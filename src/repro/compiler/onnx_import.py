"""Optional ONNX ingestion for the importer (guarded: no hard dep).

Translates a (small, feed-forward) ONNX CNN into the neutral
:class:`~repro.compiler.graph.Graph` IR, weights included, so the rest
of the pipeline (lower -> quantize -> golden -> registry) is shared
with the JSON path. ``onnx`` is probed via ``importlib`` — when absent,
:func:`onnx_available` is False and :func:`load_onnx` raises a plain
``ImportError`` explaining the optional extra; nothing else in the
compiler package imports this module's dependency, so the no-onnx
environment (the default CI leg) is fully functional.

Supported ONNX ops and their IR mapping:

=============  ==========================================================
ONNX           IR
=============  ==========================================================
Conv           ``conv`` (OIHW weights transposed to HWIO; symmetric
               ``pads`` only; ``group`` -> ``groups``)
Gemm           ``fc`` (``transB`` honoured; ``alpha``/``beta`` must be 1;
               the first Gemm after a spatial Flatten gets its weight
               rows permuted from NCHW- to NHWC-flatten order)
Relu           ``relu``
MaxPool        ``maxpool``
AveragePool /  ``avgpool`` (carried in the IR; the lowering pass rejects
GlobalAverage  it with a typed :class:`UnsupportedOpError`)
Flatten        ``flatten``
Add            ``add`` (carried; rejected at lowering)
=============  ==========================================================

Anything else raises :class:`UnsupportedOpError` naming the node —
imports fail loudly at the front door, never mid-serve.
"""

from __future__ import annotations

import importlib.util
import os

import numpy as np

from repro.compiler.graph import (INPUT, Graph, GraphError, Node,
                                  UnsupportedOpError)


def onnx_available() -> bool:
    """Probe once whether the optional ``onnx`` package is importable."""
    return importlib.util.find_spec("onnx") is not None


def load_onnx(path: str | os.PathLike) -> Graph:
    """Read an ONNX file into the neutral graph IR (weights attached).

    Raises ``ImportError`` when the optional ``onnx`` package is not
    installed, :class:`GraphError` / :class:`UnsupportedOpError` for
    models the importer cannot take.
    """
    if not onnx_available():
        raise ImportError(
            "the ONNX ingestion path needs the optional 'onnx' package "
            "(pip install onnx); the JSON/dict spec path has no such "
            "dependency")
    import onnx
    from onnx import numpy_helper

    m = onnx.load(str(path))
    g = m.graph
    inits = {t.name: numpy_helper.to_array(t) for t in g.initializer}
    graph_inputs = [i for i in g.input if i.name not in inits]
    if len(graph_inputs) != 1:
        raise GraphError(
            f"{path}: expected exactly one graph input, found "
            f"{[i.name for i in graph_inputs]}")
    inp = graph_inputs[0]
    dims = [d.dim_value
            for d in inp.type.tensor_type.shape.dim]
    if len(dims) != 4:
        raise GraphError(f"{path}: input {inp.name!r} must be NCHW "
                         f"4-d, got {dims}")
    _, c, h, w = dims
    if h != w:
        raise UnsupportedOpError(
            inp.name, f"non-square input {h}x{w} (the engine's models "
                      f"carry one square input_hw)")

    nodes: list[Node] = []
    # ONNX tensor name -> IR node name producing it.
    produced: dict[str, str] = {inp.name: INPUT}
    # IR name -> NCHW spatial shape (C, H, W) for flatten-order fixes.
    spatial: dict[str, tuple[int, int, int]] = {INPUT: (c, h, w)}
    # IR names of flatten nodes whose next Gemm needs row permutation.
    nchw_flat: dict[str, tuple[int, int, int]] = {}

    used = set()

    def fresh(name: str) -> str:
        base = name or f"n{len(nodes)}"
        out, i = base, 1
        while out in used or out == INPUT:
            out = f"{base}_{i}"
            i += 1
        used.add(out)
        return out

    for on in g.node:
        attrs = {a.name: a for a in on.attribute}
        data_in = [i for i in on.input if i not in inits]
        name = fresh(on.name or (on.output[0] if on.output else ""))
        try:
            srcs = tuple(produced[i] for i in data_in)
        except KeyError as e:
            raise GraphError(f"node {name!r}: input tensor {e.args[0]!r} "
                             f"has no producer (non-feed-forward or "
                             f"pruned graph)") from None

        if on.op_type == "Conv":
            node = _conv(on, name, srcs, attrs, inits)
            c_prev = spatial.get(srcs[0])
            if c_prev is not None:
                k = _ints(attrs, "kernel_shape", name)
                s = _ints(attrs, "strides", name, default=[1, 1])
                p = _sym_pads(attrs, name)
                oh = (c_prev[1] + 2 * p - k[0]) // s[0] + 1
                spatial[name] = (int(node.attrs["out_channels"]), oh, oh)
        elif on.op_type == "Gemm":
            node = _gemm(on, name, srcs, attrs, inits, nchw_flat)
        elif on.op_type == "Relu":
            node = Node("relu", name, srcs)
            if srcs[0] in spatial:
                spatial[name] = spatial[srcs[0]]
            if srcs[0] in nchw_flat:
                nchw_flat[name] = nchw_flat[srcs[0]]
        elif on.op_type in ("MaxPool", "AveragePool"):
            op = "maxpool" if on.op_type == "MaxPool" else "avgpool"
            k = _ints(attrs, "kernel_shape", name)
            s = _ints(attrs, "strides", name, default=list(k))
            p = _sym_pads(attrs, name)
            node = Node(op, name, srcs,
                        {"kernel": list(k), "stride": list(s),
                         "padding": p if p else "valid"})
            cp = spatial.get(srcs[0])
            if cp is not None:
                oh = (cp[1] + 2 * p - k[0]) // s[0] + 1
                spatial[name] = (cp[0], oh, oh)
        elif on.op_type == "GlobalAveragePool":
            cp = spatial.get(srcs[0])
            k = cp[1] if cp else 1
            node = Node("avgpool", name, srcs,
                        {"kernel": k, "stride": k, "padding": "valid"})
        elif on.op_type in ("Flatten", "Reshape"):
            node = Node("flatten", name, srcs[:1])
            cp = spatial.get(srcs[0])
            if cp is not None:
                nchw_flat[name] = cp
        elif on.op_type == "Add":
            node = Node("add", name, srcs)
        else:
            raise UnsupportedOpError(
                name, f"ONNX op {on.op_type!r} is outside the importable "
                      f"set (Conv, Gemm, Relu, MaxPool, AveragePool, "
                      f"GlobalAveragePool, Flatten, Reshape, Add)")
        nodes.append(node)
        for out in on.output:
            produced[out] = name

    model_name = os.path.splitext(os.path.basename(str(path)))[0]
    return Graph.build(model_name or "onnx_model", int(h), int(c), nodes)


def _ints(attrs, key, node, default=None) -> list[int]:
    if key not in attrs:
        if default is not None:
            return default
        raise GraphError(f"node {node!r}: missing ONNX attribute {key!r}")
    return list(attrs[key].ints)


def _sym_pads(attrs, node) -> int:
    """ONNX pads are [top, left, bottom, right]; the engine reproduces
    only symmetric square padding."""
    if "auto_pad" in attrs:
        ap = attrs["auto_pad"].s.decode()
        if ap and ap != "NOTSET":
            raise UnsupportedOpError(
                node, f"ONNX auto_pad={ap!r} — export with explicit "
                      f"symmetric pads")
    pads = list(attrs["pads"].ints) if "pads" in attrs else [0, 0, 0, 0]
    if len(set(pads)) != 1:
        raise UnsupportedOpError(
            node, f"asymmetric ONNX pads {pads} — the engine derives "
                  f"symmetric windows from the output arithmetic")
    return int(pads[0])


def _conv(on, name, srcs, attrs, inits) -> Node:
    w_name = on.input[1]
    if w_name not in inits:
        raise GraphError(f"node {name!r}: conv weight {w_name!r} is not "
                         f"an initializer")
    w = inits[w_name]                       # OIHW
    b = inits.get(on.input[2]) if len(on.input) > 2 else None
    k = _ints(attrs, "kernel_shape", name)
    strides = _ints(attrs, "strides", name, default=[1, 1])
    group = attrs["group"].i if "group" in attrs else 1
    if "dilations" in attrs and set(attrs["dilations"].ints) != {1}:
        raise UnsupportedOpError(
            name, f"dilated conv {list(attrs['dilations'].ints)} — the "
                  f"engine's PE array walks dense RxS windows")
    p = _sym_pads(attrs, name)
    return Node("conv", name, srcs, {
        "out_channels": int(w.shape[0]),
        "kernel": list(k),
        "stride": list(strides),
        "groups": int(group),
        "padding": p if p else "valid",
        "in_channels": None,
        "weight": np.transpose(w, (2, 3, 1, 0)).astype(np.float32),
        "bias": None if b is None else np.asarray(b, np.float32),
    })


def _gemm(on, name, srcs, attrs, inits, nchw_flat) -> Node:
    w_name = on.input[1]
    if w_name not in inits:
        raise GraphError(f"node {name!r}: Gemm weight {w_name!r} is not "
                         f"an initializer")
    for key in ("alpha", "beta"):
        if key in attrs and attrs[key].f not in (0.0, 1.0):
            raise UnsupportedOpError(
                name, f"Gemm {key}={attrs[key].f} != 1 — fold scaling "
                      f"into the weights before export")
    if "transA" in attrs and attrs["transA"].i:
        raise UnsupportedOpError(name, "Gemm transA=1 is not importable")
    w = inits[w_name]
    if "transB" in attrs and attrs["transB"].i:
        w = w.T                              # -> (in, out)
    b = inits.get(on.input[2]) if len(on.input) > 2 else None
    # The engine flattens NHWC (rows h*W*C + w*C + c); ONNX flattened
    # NCHW (rows c*H*W + h*W + w). Permute the weight rows of the first
    # Gemm after a spatial Flatten so both orders compute identically.
    src_flat = nchw_flat.get(srcs[0])
    if src_flat is not None:
        C, H, Wd = src_flat
        if w.shape[0] != C * H * Wd:
            raise GraphError(
                f"node {name!r}: Gemm in_features {w.shape[0]} != "
                f"flattened {C}x{H}x{Wd} = {C * H * Wd}")
        perm = np.asarray(
            [cc * (H * Wd) + hh * Wd + ww
             for hh in range(H) for ww in range(Wd) for cc in range(C)],
            np.int64)
        w = w[perm]
    return Node("fc", name, srcs, {
        "out_features": int(w.shape[1]),
        "in_features": None,
        "weight": np.asarray(w, np.float32),
        "bias": None if b is None else np.asarray(b, np.float32),
    })
