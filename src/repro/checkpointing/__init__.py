from repro.checkpointing.checkpoint import (latest_step, restore, save,
                                            restore_resharded)

__all__ = ["save", "restore", "latest_step", "restore_resharded"]
