"""Sharded, atomic, elastic checkpointing (no external deps).

Layout:  <dir>/step_<N>/shard_<host>.npz + manifest.json
* atomic: writes go to step_<N>.tmp, manifest last, then rename — a crashed
  writer never corrupts the latest complete step (fault-tolerance story).
* elastic: `restore_resharded` reads any complete step and re-shards to the
  current device count / mesh (used when the pod shrinks or grows; the
  allocator then re-plans the pipeline for the new resources — the paper's
  "regenerate the design for the new budget").
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16",
                                                       "float8_e4m3fn",
                                                       "float8_e5m2"):
            # widen to fp32 for .npz portability (exact for bf16/fp8);
            # restore() casts back to the target dtype.
            arr = np.asarray(leaf, dtype=np.float32)
        out[key] = arr
    return out


def save(directory: str, step: int, tree: Any, *, host_id: int = 0,
         n_hosts: int = 1, keep: int = 3) -> str:
    """Write this host's shard; host 0 writes the manifest last (atomic)."""
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **flat)
    if host_id == 0:
        manifest = {
            "step": step, "n_hosts": n_hosts,
            "keys": {k: [list(v.shape), str(v.dtype)]
                     for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)
        _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(s for s in _complete_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def _complete_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = _complete_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any, *, host_id: int = 0) -> Any:
    """Restore into the structure (and dtypes) of `like`."""
    path = os.path.join(directory, f"step_{step}")
    with np.load(os.path.join(path, f"shard_{host_id}.npz")) as z:
        data = {k: z[k] for k in z.files}
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def restore_resharded(directory: str, step: int, like: Any,
                      shardings: Any) -> Any:
    """Elastic restore: load then place under the *current* mesh shardings
    (device_put re-shards; works across different mesh shapes)."""
    tree = restore(directory, step, like)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
