"""Oracle: plain causal softmax attention (fp32 softmax)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int = 0) -> jnp.ndarray:
    """q [B,Sq,H,d], k/v [B,Skv,H,d] -> [B,Sq,H,d]."""
    B, Sq, H, d = q.shape
    Skv = k.shape[1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(d)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
