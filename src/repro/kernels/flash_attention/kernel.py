"""Pallas TPU kernel: blockwise (flash) causal attention, forward.

Grid: (batch*heads, q_tiles, kv_tiles); kv innermost sequential with the
online-softmax running max / denominator / accumulator in VMEM scratch.
Tiles are MXU-aligned (q/kv block 128+). Causal tiles fully above the
diagonal are masked out (compute-skipping for them is the `block_causal`
hillclimb variant in EXPERIMENTS.md §Perf; the baseline computes+masks).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, n_kv: int, bq: int, bkv: int, scale: float, causal: bool,
            window: int, skv: int, sq: int):
    kv = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # [bq, d]
    k = k_ref[0]                       # [bkv, d]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [bq, bkv]
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + (skv - sq)
    kpos = kv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv == n_kv - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 256, bkv: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """q [B,Sq,H,d], k/v [B,Skv,H,d] -> [B,Sq,H,d]. O(Sq*bkv) memory."""
    B, Sq, H, d = q.shape
    Skv = k.shape[1]
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    qf = q.swapaxes(1, 2).reshape(B * H, Sq, d)
    kf = k.swapaxes(1, 2).reshape(B * H, Skv, d)
    vf = v.swapaxes(1, 2).reshape(B * H, Skv, d)
    grid = (B * H, Sq // bq, Skv // bkv)
    out = pl.pallas_call(
        functools.partial(
            _kernel, n_kv=Skv // bkv, bq=bq, bkv=bkv,
            scale=1.0 / math.sqrt(d), causal=causal, window=window,
            skv=Skv, sq=Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d).swapaxes(1, 2)
