"""Jitted wrapper for blockwise attention."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def attention(q, k, v, causal: bool = True, window: int = 0,
              interpret: bool = False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)
