"""Jitted wrapper for the chunked linear recurrence."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru_scan.kernel import linear_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan(a, b, chunk: int = 256, interpret: bool = False):
    return linear_scan(a, b, chunk=chunk, interpret=interpret)
