"""Pallas TPU kernel: chunked diagonal linear recurrence.

h_t = a_t * h_{t-1} + b_t  over [B, S, D], computed in sequence chunks:
within a chunk the recurrence is expanded with a log-depth (Blelloch-style)
pass over VMEM-resident tiles; the carry h crosses chunks in a VMEM scratch
that persists across the sequential grid dimension. This is the TPU-native
replacement for the FPGA's per-row systolic update — long_500k decodes and
32k prefills of the SSM/hybrid archs are bound by this op.

Grid: (B_tiles, n_chunks) — the chunk dim is sequential ("arbitrary"
semantics), the batch dim parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(a_ref, b_ref, o_ref, h_ref, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]           # [bt, chunk, d]
    b = b_ref[...]

    # In-chunk associative scan (log depth), fp32.
    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    aa, bb = jax.lax.associative_scan(op, (a, b), axis=1)
    # Fold in the inter-chunk carry: h_t = aa_t * h_in + bb_t.
    h_in = h_ref[...]
    h = aa * h_in[:, None, :] + bb
    o_ref[...] = h.astype(o_ref.dtype)
    h_ref[...] = h[:, -1, :]


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, *, chunk: int = 256,
                bt: int = 8, interpret: bool = False) -> jnp.ndarray:
    """a, b [B,S,D] -> h [B,S,D] (fp32 recurrence, output dtype of b)."""
    B, S, D = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    bt = max(1, min(bt, B))
    while B % bt:
        bt -= 1
    grid = (B // bt, S // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, chunk, D), lambda i, c: (i, c, 0)),
            pl.BlockSpec((bt, chunk, D), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((bt, chunk, D), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), b.dtype),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
