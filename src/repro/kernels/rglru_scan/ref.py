"""Oracle for the chunked diagonal linear recurrence h_t = a_t*h_{t-1}+b_t
(RG-LRU core; RWKV6's per-channel decay uses the same primitive on its
diagonal part)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                    h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """a, b [B,S,D] float32 -> h [B,S,D]; h_{-1} = h0 or 0."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    h_init = h0 if h0 is not None else jnp.zeros_like(a[:, 0])
    _, hs = jax.lax.scan(step, h_init,
                         (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
