"""Static block-size autotuner for the Pallas kernels.

On real TPUs you would time candidates; on this CPU container we rank them
structurally — exactly the §Perf methodology (napkin math over the memory
hierarchy), encoded:

  * hard constraints: the working set of one grid step must fit VMEM
    (~16 MB/core, we budget half for double buffering), tiles must be
    MXU/VPU aligned (lane dim % 128, sublane % 8 / % 32 for int8);
  * rank: maximize MXU occupancy (tile dims vs 128x128 systolic array),
    then minimize HBM traffic = sum over grid of block bytes fetched
    (weight-stationarity falls out of this term: revisiting the same w
    block across the n-grid is free under Pallas's revolving buffers).
"""

from __future__ import annotations

import dataclasses
import math

VMEM_BYTES = 16 * 2 ** 20
VMEM_BUDGET = VMEM_BYTES // 2          # double buffering headroom
MXU = 128


@dataclasses.dataclass(frozen=True)
class GemmCandidate:
    bn: int
    bm: int
    bk: int
    vmem_bytes: int
    hbm_bytes: float      # total traffic for the whole GEMM
    mxu_occupancy: float  # fraction of the 128x128 array covered


def gemm_candidates(N: int, K: int, M: int, *, in_bytes: int = 1,
                    acc_bytes: int = 4,
                    tiles=(128, 256, 512)) -> list[GemmCandidate]:
    out = []
    for bn in tiles:
        for bm in tiles:
            for bk in tiles:
                vmem = (bn * bk + bk * bm) * in_bytes + bn * bm * acc_bytes
                if vmem > VMEM_BUDGET:
                    continue
                gn = math.ceil(N / bn)
                gm = math.ceil(M / bm)
                gk = math.ceil(K / bk)
                # x block fetched once per (n, k) [revisited across m],
                # w block once per (m, k) [revisited across n under the
                # sequential k-inner grid], out written once per (n, m).
                hbm = (gn * gk * bn * bk * in_bytes * gm ** 0
                       + gm * gk * bk * bm * in_bytes
                       + gn * gm * bn * bm)
                occ = min(1.0, bn / MXU) * min(1.0, bm / MXU) \
                    * min(1.0, bk / MXU)
                out.append(GemmCandidate(bn, bm, bk, vmem, hbm, occ))
    return out


def pick_gemm_blocks(N: int, K: int, M: int, **kw) -> GemmCandidate:
    """Best candidate: max MXU occupancy, then min HBM traffic, then min
    VMEM (leave room for the pipeline)."""
    cands = gemm_candidates(N, K, M, **kw)
    if not cands:
        raise ValueError("no feasible block config fits VMEM")
    return min(cands, key=lambda c: (-c.mxu_occupancy, c.hbm_bytes,
                                     c.vmem_bytes))


@dataclasses.dataclass(frozen=True)
class AttnCandidate:
    bq: int
    bkv: int
    vmem_bytes: int
    hbm_bytes: float


def pick_attention_blocks(S: int, d: int, *, dtype_bytes: int = 2,
                          tiles=(128, 256, 512)) -> AttnCandidate:
    """Flash-attention q/kv tile sizes: fit q-tile + kv-tile + fp32
    scratch in VMEM; minimize KV re-reads (k/v fetched S/bq times)."""
    best = None
    for bq in tiles:
        for bkv in tiles:
            if bq > S or bkv > S:
                continue
            vmem = (bq * d + 2 * bkv * d) * dtype_bytes \
                + bq * (d + 2) * 4 + bq * bkv * 4
            if vmem > VMEM_BUDGET:
                continue
            hbm = (S * d                       # q once
                   + 2 * S * d * math.ceil(S / bq)   # k/v per q tile
                   + S * d) * dtype_bytes
            c = AttnCandidate(bq, bkv, vmem, hbm)
            if best is None or (c.hbm_bytes, c.vmem_bytes) < (
                    best.hbm_bytes, best.vmem_bytes):
                best = c
    if best is None:
        raise ValueError("no feasible attention tiling fits VMEM")
    return best
