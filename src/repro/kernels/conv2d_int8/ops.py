"""Jitted wrappers: conv2d as im2col + the int8 GEMM Pallas kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_int8.kernel import gemm_int8


@partial(jax.jit, static_argnames=("stride", "interpret", "emit_int32"))
def conv2d_int8(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
                stride: int = 1, interpret: bool = False,
                emit_int32: bool = False) -> jnp.ndarray:
    """x [B,H,W,C] int8, w [R,S,C,M] int8, shift [M] -> int8 [B,H',W',M].

    im2col (the line-buffer address generator) runs in XLA; the MAC array +
    requantize pipeline is the Pallas kernel.
    """
    R, S, C, M = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32), (R, S), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(jnp.int8)
    B, Ho, Wo, K = patches.shape
    wt = jnp.transpose(w, (2, 0, 1, 3)).reshape(R * S * C, M)
    out = gemm_int8(patches.reshape(-1, K), wt, shift, interpret=interpret,
                    emit_int32=emit_int32)
    return out.reshape(B, Ho, Wo, M)
