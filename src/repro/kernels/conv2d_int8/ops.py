"""Jitted wrappers: conv2d / fc as int8 im2col + the int8 GEMM Pallas
kernel with the fused bias/ReLU/requantize epilogue.

The im2col (the line-buffer address generator) runs in XLA as pure int8
slicing — no float32 patch materialization; the MAC array + output
pipeline is the Pallas kernel. Grouped convolutions (e.g. AlexNet's
two-tower layers) run one weight-stationary GEMM per group, exactly like
the paper's per-engine channel split.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.conv2d_int8.kernel import gemm_int8
from repro.kernels.conv2d_int8.ref import conv2d_int8_via


@partial(jax.jit, static_argnames=("stride", "padding", "groups", "relu",
                                   "interpret", "emit_int32"))
def conv2d_int8(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
                bias: jnp.ndarray | None = None, *, stride: int = 1,
                padding="same", groups: int = 1, relu: bool = False,
                interpret: bool = False,
                emit_int32: bool = False) -> jnp.ndarray:
    """x [B,H,W,C] int8, w [R,S,C/groups,M] int8, shift/bias [M] int32 ->
    int8 [B,Ho,Wo,M] (int32 with ``emit_int32``).

    ``padding`` is "same" or an explicit ((top, bottom), (left, right));
    ``stride`` and ``groups`` are arbitrary, so every conv shape in the
    paper's four models (stride-4/stride-2 stems, grouped towers) takes
    this route.
    """
    return conv2d_int8_via(gemm_int8, x, w, shift, bias, stride=stride,
                           padding=padding, groups=groups, relu=relu,
                           interpret=interpret, emit_int32=emit_int32)


@partial(jax.jit, static_argnames=("relu", "interpret", "emit_int32"))
def fc_int8(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
            bias: jnp.ndarray | None = None, *, relu: bool = False,
            interpret: bool = False,
            emit_int32: bool = False) -> jnp.ndarray:
    """Fully-connected layer on the same GEMM engine: x [B,F] int8,
    w [F,M] int8 -> int8 [B,M] (int32 with ``emit_int32``)."""
    return gemm_int8(x, w, shift, bias, relu=relu, interpret=interpret,
                     emit_int32=emit_int32)
