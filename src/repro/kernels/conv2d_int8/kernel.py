"""Pallas TPU kernel: weight-stationary int8 implicit-GEMM conv engine.

Hardware mapping of the paper's PE array (DESIGN.md §2):
  * the M' x C' x R x S multiplier grid  ->  one MXU tile pair
    (bk x bm int8 GEMM tile, int32 accumulate);
  * K-row groups                        ->  the bn tile of im2col rows;
  * weight-stationary reuse             ->  w block revisited across the
    n-grid (Pallas keeps it in VMEM; index_map pins the same block);
  * bias add + ReLU + per-channel shift ->  the epilogue on the last
    k-step (Fig. 3(c)) — the full requantize pipeline is fused, so
    activations leave the engine already in int8.

Grid: (n_tiles, m_tiles, k_tiles) with k innermost (sequential,
accumulating into an int32 VMEM scratch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant


def _kernel(x_ref, w_ref, bias_ref, shift_ref, o_ref, acc_ref, *, n_k: int,
            relu: bool = False, emit_int32: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_ref[...].astype(jnp.int32)          # [bn, bk]
    b = w_ref[...].astype(jnp.int32)          # [bk, bm]
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        # The paper's output stage, fused: 32-bit partial sums + bias, ReLU,
        # per-output-channel shift onto the activation format, truncate.
        acc = acc_ref[...] + bias_ref[...].astype(jnp.int32)[None, :]
        if relu:
            acc = jnp.maximum(acc, 0)
        if emit_int32:
            # Raw 32-bit partial sums (the psumSpad view, pre-requantize).
            o_ref[...] = acc
        else:
            sh = shift_ref[...].astype(jnp.int32)[None, :]  # [1, bm]
            # shift >= 0: right-shift + truncate; shift < 0: the left-shift
            # branch of the Fig. 3(c) aligner (output format finer than the
            # accumulator's), saturating instead of wrapping int32.
            y = quant.saturating_signed_shift(acc, sh)
            o_ref[...] = jnp.clip(y, -128, 127).astype(jnp.int8)


def gemm_int8(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
              bias: jnp.ndarray | None = None, *, relu: bool = False,
              bn: int = 256, bm: int = 256, bk: int = 256,
              interpret: bool = False,
              emit_int32: bool = False) -> jnp.ndarray:
    """int8 GEMM with fused requantize epilogue: [N,K]x[K,M] -> int8 [N,M].

    ``out = clip((relu?)(x @ w + bias) >> shift)`` with per-column (output
    channel) ``shift``/``bias``; negative shifts left-shift. With
    ``emit_int32`` the epilogue stops after bias/ReLU and returns the raw
    int32 accumulators.

    Block sizes are MXU-aligned (multiples of 128 for the lane dim, 32 for
    int8 sublanes). N/K/M are padded to the block grid.
    """
    N, K = x.shape
    K2, M = w.shape
    assert K == K2, (x.shape, w.shape)
    if bias is None:
        bias = jnp.zeros((M,), jnp.int32)
    bn_, bm_, bk_ = min(bn, _rnd(N)), min(bm, _rnd(M)), min(bk, _rnd(K))
    Np, Mp, Kp = _pad(N, bn_), _pad(M, bm_), _pad(K, bk_)
    xp = jnp.pad(x, ((0, Np - N), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Mp - M)))
    bp = jnp.pad(bias.astype(jnp.int32), (0, Mp - M))
    sp = jnp.pad(shift.astype(jnp.int32), (0, Mp - M))
    n_k = Kp // bk_
    grid = (Np // bn_, Mp // bm_, n_k)
    out_dt = jnp.int32 if emit_int32 else jnp.int8
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, relu=relu,
                          emit_int32=emit_int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn_, bk_), lambda n, m, k: (n, k)),
            pl.BlockSpec((bk_, bm_), lambda n, m, k: (k, m)),
            pl.BlockSpec((bm_,), lambda n, m, k: (m,)),
            pl.BlockSpec((bm_,), lambda n, m, k: (m,)),
        ],
        out_specs=pl.BlockSpec((bn_, bm_), lambda n, m, k: (n, m)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), out_dt),
        scratch_shapes=[pltpu.VMEM((bn_, bm_), jnp.int32)],
        interpret=interpret,
    )(xp, wp, bp, sp)
    return out[:N, :M]


def _rnd(n: int, to: int = 128) -> int:
    return max(to, (n + to - 1) // to * to)


def _pad(n: int, b: int) -> int:
    return (n + b - 1) // b * b
