"""Pure-jnp oracle for the int8 conv/GEMM engine (paper Fig. 3).

The hardware pipeline: int8 activations x int8 weights -> int32 partial
sums -> (+bias, ReLU) -> per-output-channel shift + truncate to int8. The
conv is expressed as an implicit GEMM over int8 im2col patches (the
activation line buffer's address generation), which is exactly what the
Pallas kernel computes in MXU tiles. Patch features are ordered
``(r, s, c)`` so ``w[R,S,C,M].reshape(R*S*C, M)`` matches directly.
"""

from __future__ import annotations

import jax.numpy as jnp

Pad2 = tuple[tuple[int, int], tuple[int, int]]


def requantize_ref(acc: jnp.ndarray, shift: jnp.ndarray,
                   bias: jnp.ndarray | None = None,
                   relu: bool = False) -> jnp.ndarray:
    """The fused epilogue on raw int32 accumulators: bias add, optional
    ReLU, then the shared saturating signed shift + clip to int8
    (``quant.requantize_output`` — the Pallas kernel epilogue inlines the
    identical math, pinned by the bit-identity tests)."""
    from repro.core import quant
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    if relu:
        acc = jnp.maximum(acc, 0)
    return quant.requantize_output(acc, 0, shift[None, :].astype(jnp.int32),
                                   bits=8)


def gemm_int8_ref(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
                  bias: jnp.ndarray | None = None,
                  relu: bool = False) -> jnp.ndarray:
    """x [N, K] int8, w [K, M] int8, shift [M] int32 (signed shift bits).
    Returns int8 [N, M]: clip((relu?)(x @ w + bias) >> shift)."""
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return requantize_ref(acc, shift, bias, relu)


def same_padding(in_hw: int, kernel: int, stride: int) -> tuple[int, int]:
    """TF/XLA "SAME" pad pair for one spatial dim."""
    out = -(-in_hw // stride)
    total = max((out - 1) * stride + kernel - in_hw, 0)
    return total // 2, total - total // 2


def im2col_int8(x: jnp.ndarray, R: int, S: int, stride: int,
                pad: Pad2) -> jnp.ndarray:
    """int8 im2col with no float materialization: x [B,H,W,C] ->
    [B,Ho,Wo,R*S*C], features ordered (r, s, c). ``pad`` is
    ((top, bottom), (left, right)); zero-padding is exact for the
    symmetric (zero-point-0) po2 formats."""
    xp = jnp.pad(x, ((0, 0), pad[0], pad[1], (0, 0)))
    Hp, Wp = xp.shape[1], xp.shape[2]
    Ho = (Hp - R) // stride + 1
    Wo = (Wp - S) // stride + 1
    cols = [xp[:, r:r + (Ho - 1) * stride + 1:stride,
               s:s + (Wo - 1) * stride + 1:stride, :]
            for r in range(R) for s in range(S)]
    return jnp.concatenate(cols, axis=-1)


def _resolve_pad(padding, in_h: int, in_w: int, R: int, S: int,
                 stride: int) -> Pad2:
    if padding == "same":
        return same_padding(in_h, R, stride), same_padding(in_w, S, stride)
    return tuple(tuple(p) for p in padding)  # type: ignore[return-value]


def conv2d_int8_via(gemm_fn, x: jnp.ndarray, w: jnp.ndarray,
                    shift: jnp.ndarray, bias: jnp.ndarray | None = None, *,
                    stride: int = 1, padding="same", groups: int = 1,
                    relu: bool = False, **gemm_kwargs) -> jnp.ndarray:
    """Conv as implicit GEMM over any engine: one weight-stationary
    ``gemm_fn(patches, w2d, shift, bias, relu=..., **gemm_kwargs)`` per
    channel group. Shared by the jnp oracle and the Pallas route so the
    spatial plumbing (stride, asymmetric padding, groups) cannot drift."""
    R, S, Cg, M = w.shape
    B, H, W, C = x.shape
    assert C == Cg * groups and M % groups == 0, (x.shape, w.shape, groups)
    pad = _resolve_pad(padding, H, W, R, S, stride)
    outs = []
    Mg = M // groups
    for g in range(groups):
        xg = x[..., g * Cg:(g + 1) * Cg]
        patches = im2col_int8(xg, R, S, stride, pad)
        Bp, Ho, Wo, K = patches.shape
        wg = w[..., g * Mg:(g + 1) * Mg].reshape(R * S * Cg, Mg)
        bg = None if bias is None else bias[g * Mg:(g + 1) * Mg]
        out = gemm_fn(patches.reshape(-1, K), wg,
                      shift[g * Mg:(g + 1) * Mg], bg, relu=relu,
                      **gemm_kwargs)
        outs.append(out.reshape(B, Ho, Wo, Mg))
    return outs[0] if groups == 1 else jnp.concatenate(outs, axis=-1)


def conv2d_int8_ref(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
                    bias: jnp.ndarray | None = None, *, stride: int = 1,
                    padding="same", groups: int = 1,
                    relu: bool = False) -> jnp.ndarray:
    """x [B,H,W,C] int8, w [R,S,C/groups,M] int8, shift/bias [M].
    Arbitrary stride, asymmetric padding ((top,bot),(left,right)) or
    "same", and grouped channels. Returns int8 [B,Ho,Wo,M]."""
    return conv2d_int8_via(gemm_int8_ref, x, w, shift, bias, stride=stride,
                           padding=padding, groups=groups, relu=relu)
