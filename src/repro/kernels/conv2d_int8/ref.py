"""Pure-jnp oracle for the int8 conv/GEMM engine (paper Fig. 3).

The hardware pipeline: int8 activations x int8 weights -> int32 partial
sums -> per-output-channel right-shift + truncate to int8. The conv is
expressed as an implicit GEMM over im2col patches (the activation line
buffer's address generation), which is exactly what the Pallas kernel
computes in MXU tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_int8_ref(x: jnp.ndarray, w: jnp.ndarray,
                  shift: jnp.ndarray) -> jnp.ndarray:
    """x [N, K] int8, w [K, M] int8, shift [M] int32 (right-shift bits).
    Returns int8 [N, M]: clip((x @ w) >> shift)."""
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    y = jnp.right_shift(acc, shift[None, :].astype(jnp.int32))
    return jnp.clip(y, -128, 127).astype(jnp.int8)


def conv2d_int8_ref(x: jnp.ndarray, w: jnp.ndarray, shift: jnp.ndarray,
                    stride: int = 1) -> jnp.ndarray:
    """x [B,H,W,C] int8, w [R,S,C,M] int8 (SAME padding), shift [M].
    Returns int8 [B,H',W',M]."""
    R, S, C, M = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32), (R, S), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(jnp.int8)
    B, Ho, Wo, K = patches.shape
    # conv_general_dilated_patches emits features as [C, R, S] blocks.
    wt = jnp.transpose(w, (2, 0, 1, 3)).reshape(R * S * C, M)
    out = gemm_int8_ref(patches.reshape(-1, K), wt, shift)
    return out.reshape(B, Ho, Wo, M)
