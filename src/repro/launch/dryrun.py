import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, with NO parameter allocation
(ShapeDtypeStruct stand-ins), and extract the roofline inputs:

  * compiled.memory_analysis()  -> bytes per device (fits-in-HBM proof)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes accessed
  * lowered HLO text            -> per-collective operand bytes

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k \
      --dist pipeline --stages 4    # the paper's pipeline path
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ARCHS, get as get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, cache_specs, cell_is_runnable,
                                 input_specs)
from repro.launch import steps as STEPS
from repro.runtime import sharding as SH

from repro.launch.hlo_stats import collective_bytes  # noqa: E402


def _mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multipod"))


def run_cell(arch: str, shape: str, mesh_name: str, dist: str = "pjit",
             stages: int = 0, quant: str = "none") -> dict:
    cfg = get_arch(arch)
    case = SHAPES[shape]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    mesh = _mesh_for(mesh_name)
    t0 = time.time()
    with compat.set_mesh(mesh):
        if dist == "pipeline":
            res = _run_pipeline_cell(cfg, case, mesh, mesh_name, stages)
        else:
            res = _run_pjit_cell(cfg, case, mesh, mesh_name,
                                 dp_model=(dist == "dp"), quant=quant)
    res.update(arch=arch, shape=shape, mesh=mesh_name, dist=dist,
               quant=quant, compile_s=round(time.time() - t0, 1),
               status="ok")
    return res


def _analyze(lowered, compiled, n_dev: int) -> dict:
    out: dict = {}
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        out["memory_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed",
                             "bytes accessed output", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        out["cost_error"] = str(e)
    try:
        txt = compiled.as_text()
    except Exception:
        txt = lowered.as_text()
    out["collectives"] = collective_bytes(txt)
    out["n_devices"] = n_dev
    return out


def _run_pjit_cell(cfg, case, mesh, mesh_name, dp_model: bool = False,
                   quant: str = "none") -> dict:
    n_dev = mesh.devices.size
    batch_sds = input_specs(cfg, case)
    if dp_model:
        # Repurpose the model axis as extra data parallelism (small-model
        # optimization, EXPERIMENTS.md §Perf): params replicated over it,
        # batch sharded over (pod, data, model).
        batch_sh = _dp_batch_shardings(mesh, batch_sds)
    else:
        batch_sh = SH.batch_shardings(mesh, batch_sds,
                                      seq_shard=(case.mode == "prefill"))

    if case.mode == "train":
        params_sds, opt_sds = STEPS.abstract_state(cfg)
        param_sh = SH.param_shardings(cfg, mesh, params_sds,
                                      fsdp=None if not dp_model else False)
        if dp_model:
            param_sh = jax.tree.map(_strip_model_axis, param_sh)
        opt_sh = _opt_shardings(opt_sds, param_sh, mesh)
        step = STEPS.make_train_step(cfg)
        lowered = jax.jit(
            step, in_shardings=(param_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1),
        ).lower(params_sds, opt_sds, batch_sds)
    else:
        from repro.models import layers as LYR
        from repro.models import transformer as TF
        params_sds = jax.eval_shape(lambda: TF.init_params(cfg))
        if quant == "int8":
            params_sds = jax.eval_shape(LYR.quantize_params_int8,
                                        params_sds)
        param_sh = SH.param_shardings(cfg, mesh, params_sds)
        cache_sds = cache_specs(cfg, case)
        cache_sh = SH.cache_shardings(mesh, cache_sds)
        if case.mode == "prefill":
            step = STEPS.make_prefill_step(cfg)
        else:
            step = STEPS.make_serve_step(cfg)
        lowered = jax.jit(
            step, in_shardings=(param_sh, cache_sh, batch_sh),
            donate_argnums=(1,),
        ).lower(params_sds, cache_sds, batch_sds)
    compiled = lowered.compile()
    res = _analyze(lowered, compiled, n_dev)
    print(compiled.memory_analysis())
    return res


def _strip_model_axis(sh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = tuple(None if ax == "model" else ax for ax in sh.spec)
    return NamedSharding(sh.mesh, P(*spec))


def _dp_batch_shardings(mesh, batch_shape):
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim and leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, batch_shape)


def _opt_shardings(opt_sds, param_sh, mesh):
    """Moments inherit param shardings; ZeRO-1 additionally splits the
    first still-replicated dim over 'data' when divisible. q8-encoded
    moments shard their block dim over the whole mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n_all = 1
    for a in axes:
        n_all *= mesh.shape[a]

    def is_q8(n):
        return isinstance(n, dict) and set(n) == {"q", "scale", "shape"}

    def inherit(sds, psh):
        if is_q8(sds):
            blocks = sds["q"].shape[0]
            spec = P(axes) if blocks % n_all == 0 else P()
            return {"q": NamedSharding(mesh, spec),
                    "scale": NamedSharding(mesh, spec),
                    "shape": NamedSharding(mesh, P())}
        spec = list(psh.spec) + [None] * (sds.ndim - len(psh.spec))
        if "data" in mesh.shape and "data" not in spec:
            nd = mesh.shape["data"]
            for i, s in enumerate(spec):
                if s is None and sds.shape[i] % nd == 0 and sds.shape[i] >= nd:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    from repro.optim import AdamWState
    mu = jax.tree.map(inherit, opt_sds.mu, param_sh, is_leaf=is_q8)
    nu = jax.tree.map(inherit, opt_sds.nu, param_sh, is_leaf=is_q8)
    err = (jax.tree.map(lambda _: NamedSharding(mesh, P()), opt_sds.err)
           if opt_sds.err is not None else None)
    return AdamWState(NamedSharding(mesh, P()), mu, nu, err)


def _run_pipeline_cell(cfg, case, mesh, mesh_name, stages: int) -> dict:
    """The paper's flexible-pipeline path: model axis -> stage x tp."""
    from repro.core import pipeline as PL
    from repro.core.allocator import plan_pipeline
    from repro.core.workload import lm_layer_workloads

    if case.mode not in ("train", "prefill"):
        raise ValueError("pipeline dry-run covers train/prefill shapes")
    if not PL.supports_pipeline(cfg):
        return {"status": "unsupported", "reason": "unit kind"}
    train = case.mode == "train"
    layers = lm_layer_workloads(cfg, seq_len=case.seq_len,
                                batch=case.global_batch, mode=case.mode)
    n_pod = mesh.shape.get("pod", 1)
    plan = plan_pipeline(
        layers, model_axis=16, data_axis=16 * n_pod,
        global_batch=case.global_batch, seq_len=case.seq_len, train=train,
        d_model=cfg.d_model, allow_infeasible=not train,
        stage_choices=[stages] if stages else None)
    S, T = plan.n_stages, plan.tensor_parallel
    pmesh = PL.make_pipeline_mesh(16, S, T, n_pod=n_pod)
    params, kind = PL.build_pipeline_params(cfg, S, abstract=True)
    mask_shape = params.pop("unit_mask")
    import numpy as np
    mask = jnp.asarray(np.ones(mask_shape.shape, bool))
    units_shape = params["units"]
    K = min(plan.microbatches,
            case.global_batch // (16 * n_pod))
    K = max(K, 1)
    ctx = PL.PipelineContext(cfg=cfg, unit_kind=kind, S=S, T=T, n_micro=K)
    with compat.set_mesh(pmesh):
        batch_sds = input_specs(cfg, case)
        if train:
            loss_fn = PL.pipeline_loss_fn(ctx, pmesh, units_shape,
                                          unit_mask=mask)
            lowered = jax.jit(jax.grad(loss_fn)).lower(params, batch_sds)
        else:
            fn = PL.pipeline_prefill_fn(ctx, pmesh, units_shape,
                                        unit_mask=mask)
            lowered = jax.jit(fn).lower(params, batch_sds)
        compiled = lowered.compile()
        res = _analyze(lowered, compiled, pmesh.devices.size)
        print(compiled.memory_analysis())
    res["plan"] = {"S": S, "T": T, "microbatches": K,
                   "boundaries": list(plan.boundaries)[:8],
                   "predicted_util": plan.utilization}
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod",
                                                      "both"))
    ap.add_argument("--dist", default="pjit",
                    choices=("pjit", "pipeline", "dp"))
    ap.add_argument("--quant", default="none", choices=("none", "int8"))
    ap.add_argument("--stages", type=int, default=0)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}_{shape}_{mesh_name}_{args.dist}"
                if args.quant != "none":
                    tag += f"_{args.quant}"
                try:
                    res = run_cell(arch, shape, mesh_name, args.dist,
                                   args.stages, args.quant)
                except Exception as e:
                    res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "dist": args.dist, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
                print(f"[{res['status']:9s}] {tag} "
                      f"({res.get('compile_s', '-')}s)", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
