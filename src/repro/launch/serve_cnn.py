"""CNN serving launcher: stream frames through a compiled EngineProgram.

Serves any of the four paper models (vgg16 / alexnet / zf / yolo) either
from a single jitted step chain (:class:`repro.core.executor
.EngineExecutor`) or through the stage-pipelined serving subsystem
(``--stages K``: :class:`repro.serving.PipelineExecutor` + the async
:class:`repro.serving.AsyncFrontend`), reporting measured steady-state
FPS next to the Algorithm-1 predicted FPS of the same plan (the paper's
modeled pipeline throughput on the ZC706-class budget) — plus request
latency percentiles for the async path.

With ``--qos`` (or ``--traffic-mix`` / ``--slo-ms``) the stream is a
mixed-traffic arrival process through the QoS frontend: priority lanes,
per-request deadlines with drop-on-SLO-miss, and per-class latency split
into queueing / assembly / compute — with the expedited flush and the
(default-on) estimated-wait admission control driven by an online EWMA
service-time estimate warm-started from the calibration pass.
``--knee`` instead runs the bracketing absolute-QPS sweep and reports
the capacity knee: the max sustained rate at which the interactive
class misses its SLO less than ``--miss-target`` of the time.
``--place-stages`` pins stage i to ``jax.devices()[i % n]``
(transparent on a single device). ``--replicas R`` (with
``--replica-mode pipeline|stage-shard``) serves through R routed
pipeline replicas (:class:`repro.serving.ReplicaPool`): each ready
micro-batch goes to the replica with the least estimated wait, and the
fleet's knee scales with R on a multi-device backend (force one on CPU
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

This module is the CLI only. The serving engine itself — registry,
server lifecycle, and the ``serve``/``serve_async``/``serve_qos``/
``serve_knee`` measurement paths — lives in
:mod:`repro.serving.server`; multi-model (multi-tenant) serving is
exercised by ``benchmarks/serve_multi_bench.py`` over the same engine.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16 --stages 2 --max-wait-ms 10
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16 --stages 2 --qos --slo-ms 200 \
      --traffic-mix "interactive:1:0.25:slo,batch:0:0.75"
"""

from __future__ import annotations

import argparse

from repro.core import workload as W
from repro.serving.server import (compile_for_serving, serve, serve_async,
                                  serve_knee, serve_knee_rescale,
                                  serve_qos, synthetic_stream)

# Historical import surface: the serve paths started life in this
# module, and the benches/tests import them from here.
__all__ = ["compile_for_serving", "synthetic_stream", "serve",
           "serve_async", "serve_qos", "serve_knee", "serve_knee_rescale",
           "main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="alexnet",
                    choices=sorted(W.CNN_MODELS))
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--bits", type=int, default=8, choices=(8, 16))
    ap.add_argument("--route", default=None,
                    choices=("f32", "oracle", "kernel"),
                    help="MAC lowering (default: f32 for int8)")
    ap.add_argument("--eager-frames", type=int, default=0,
                    help="also time N frames through the eager loop")
    ap.add_argument("--output", default="top1",
                    choices=("top1", "logits"))
    ap.add_argument("--stages", type=int, default=0,
                    help="serve through the K-stage pipelined subsystem "
                         "with the async frontend (0 = single-jit path)")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="dynamic batcher flush timeout (async path; "
                         "default: one full-batch window at the arrival "
                         "rate)")
    ap.add_argument("--arrival-fps", type=float, default=None,
                    help="open-loop request rate (default: 70%% of the "
                         "measured pipeline throughput)")
    ap.add_argument("--place-stages", action="store_true",
                    help="pin stage i to jax.devices()[i %% n] "
                         "(transparent on a single device)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through R routed pipeline replicas "
                         "(ReplicaPool + least-estimated-wait router; "
                         "implies the pipelined subsystem)")
    ap.add_argument("--replica-mode", default="pipeline",
                    choices=("pipeline", "stage-shard"),
                    help="replica placement: whole pipeline per device, "
                         "or stages sharded across each replica's "
                         "contiguous device slice")
    ap.add_argument("--qos", action="store_true",
                    help="serve a mixed-traffic stream through the QoS "
                         "frontend (priority lanes + deadlines) and "
                         "report per-class phase-split latency")
    ap.add_argument("--knee", action="store_true",
                    help="bracketing absolute-QPS sweep: report the max "
                         "sustained rate with interactive miss rate "
                         "under --miss-target (the capacity knee)")
    ap.add_argument("--miss-target", type=float, default=0.01,
                    help="armed-class SLO miss rate defining 'sustained' "
                         "for --knee (default 0.01)")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable estimated-wait admission control "
                         "(PR-4 lane-bound-only admission)")
    ap.add_argument("--flush-guard-ms", type=float, default=None,
                    help="fixed expedited-flush guard margin (default: "
                         "adaptive, 25%% of the service estimate + 2ms)")
    ap.add_argument("--traffic-mix", default=None,
                    help="QoS mix as name:priority:share[:deadline_ms] "
                         "comma-separated ('slo' = --slo-ms; default: "
                         "interactive:1:0.25:slo,batch:0:0.75)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="deadline for the default interactive class "
                         "(implies --qos)")
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream RNG seed")
    ap.add_argument("--quick", action="store_true",
                    help="small smoke setting (8 frames, batch 4)")
    args = ap.parse_args(argv)
    if args.quick:
        args.frames, args.batch = 8, 4
    qos = args.qos or args.traffic_mix is not None or args.slo_ms is not None
    if args.knee or qos:
        from repro.serving import parse_traffic_mix
        # slo_ms=None lets serve_qos derive a feasible deadline from
        # the measured service time; only an explicit --slo-ms pins it
        # (and is required when --traffic-mix uses the 'slo' token).
        mix = (parse_traffic_mix(args.traffic_mix, args.slo_ms)
               if args.traffic_mix else None)
    if args.knee:
        serve_knee(args.model, frames=args.frames, batch=args.batch,
                   stages=max(args.stages, 1), bits=args.bits,
                   route=args.route, seed=args.seed, slo_ms=args.slo_ms,
                   traffic_mix=mix, miss_target=args.miss_target,
                   max_wait_ms=args.max_wait_ms,
                   flush_guard_ms=args.flush_guard_ms,
                   admission_control=not args.no_admission,
                   place_stages=args.place_stages,
                   replicas=args.replicas,
                   replica_mode=args.replica_mode, output=args.output)
    elif qos:
        serve_qos(args.model, frames=args.frames, batch=args.batch,
                  stages=max(args.stages, 1), bits=args.bits,
                  route=args.route, seed=args.seed, slo_ms=args.slo_ms,
                  traffic_mix=mix, arrival_fps=args.arrival_fps,
                  max_wait_ms=args.max_wait_ms,
                  admission_control=not args.no_admission,
                  flush_guard_ms=args.flush_guard_ms,
                  place_stages=args.place_stages,
                  replicas=args.replicas,
                  replica_mode=args.replica_mode, output=args.output)
    elif args.stages > 0 or args.replicas > 1:
        serve_async(args.model, frames=args.frames, batch=args.batch,
                    stages=max(args.stages, 1), bits=args.bits,
                    route=args.route, max_wait_ms=args.max_wait_ms,
                    arrival_fps=args.arrival_fps, output=args.output,
                    place_stages=args.place_stages,
                    replicas=args.replicas,
                    replica_mode=args.replica_mode, seed=args.seed)
    else:
        serve(args.model, frames=args.frames, batch=args.batch,
              bits=args.bits, route=args.route, seed=args.seed,
              eager_frames=args.eager_frames, output=args.output)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
