"""CNN serving launcher: stream frames through a compiled EngineProgram.

Serves any of the four paper models (vgg16 / alexnet / zf / yolo) either
from a single jitted step chain (:class:`repro.core.executor
.EngineExecutor`) or through the stage-pipelined serving subsystem
(``--stages K``: :class:`repro.serving.PipelineExecutor` + the async
:class:`repro.serving.AsyncFrontend`), reporting measured steady-state
FPS next to the Algorithm-1 predicted FPS of the same plan (the paper's
modeled pipeline throughput on the ZC706-class budget) — plus request
latency percentiles for the async path.

With ``--qos`` (or ``--traffic-mix`` / ``--slo-ms``) the stream is a
mixed-traffic arrival process through the QoS frontend: priority lanes,
per-request deadlines with drop-on-SLO-miss, and per-class latency split
into queueing / assembly / compute — with the expedited flush and the
(default-on) estimated-wait admission control driven by an online EWMA
service-time estimate warm-started from the calibration pass.
``--knee`` instead runs the bracketing absolute-QPS sweep and reports
the capacity knee: the max sustained rate at which the interactive
class misses its SLO less than ``--miss-target`` of the time.
``--place-stages`` pins stage i to ``jax.devices()[i % n]``
(transparent on a single device). ``--replicas R`` (with
``--replica-mode pipeline|stage-shard``) serves through R routed
pipeline replicas (:class:`repro.serving.ReplicaPool`): each ready
micro-batch goes to the replica with the least estimated wait, and the
fleet's knee scales with R on a multi-device backend (force one on CPU
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16 --stages 2 --max-wait-ms 10
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16 --stages 2 --qos --slo-ms 200 \
      --traffic-mix "interactive:1:0.25:slo,batch:0:0.75"
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core import workload as W
from repro.core.executor import EngineExecutor
from repro.core.program import compile_model
from repro.models import cnn


def compile_for_serving(model_name: str, *, bits: int = 8, seed: int = 0,
                        theta: int | None = None):
    """Compile ``model_name`` exactly as the serve paths consume it:
    seeded params, seeded calibration batch, Table I's budget convention
    for the bit width (the plan only affects modeled numbers — never the
    executed arithmetic)."""
    m = W.CNN_MODELS[model_name]()
    params = cnn.init_params(m, jax.random.PRNGKey(seed))
    calib = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (1, m.input_hw, m.input_hw,
                                       m.input_ch))
    # 8-bit double-pumps the 900 DSPs, so modeled_fps_alg1 here equals
    # the fps8/fps16 column in benchmarks/table1.py.
    if theta is None:
        theta = 2 * 900 - len(m.layers) if bits == 8 else 900
    kwargs = {"theta": theta,
              "bram_total": None if bits == 8 else 545}
    return compile_model(m, params, bits=bits, calib_batch=calib, **kwargs)


def synthetic_stream(model_name: str, frames: int,
                     seed: int = 0) -> np.ndarray:
    """The seeded synthetic frame stream every serve/bench entry point
    shares (explicit RNG: identical frames run to run)."""
    m = W.CNN_MODELS[model_name]()
    rng = np.random.default_rng(seed + 2)
    return rng.standard_normal(
        (frames, m.input_hw, m.input_hw, m.input_ch), dtype=np.float32)


def serve(model_name: str, *, frames: int = 64, batch: int = 16,
          bits: int = 8, route: str | None = None, seed: int = 0,
          theta: int | None = None, eager_frames: int = 0,
          output: str = "top1", verbose: bool = True) -> dict:
    """Compile ``model_name``, serve ``frames`` synthetic frames, return a
    result dict (measured/modeled FPS). ``eager_frames > 0`` also times
    the eager per-sample reference loop for comparison."""
    if frames <= batch:
        raise ValueError(
            f"frames={frames} <= batch={batch}: the whole stream fits in "
            f"the first micro-batch, which is charged to compile/warmup, "
            f"leaving no steady-state window to measure (steady_fps would "
            f"be 0). Use frames >= 2*batch.")
    prog = compile_for_serving(model_name, bits=bits, seed=seed, theta=theta)
    stream = synthetic_stream(model_name, frames, seed)

    ex = EngineExecutor(prog, batch_size=batch, route=route, output=output)
    outs = ex.serve(stream)
    st = ex.stats

    # cache_size() counts XLA executables (1 = compiled once, never
    # recompiled); -1 means the running jax doesn't expose the counter.
    n_exec = ex.runner.cache_size()
    result = {
        "model": model_name,
        "bits": bits,
        "route": ex.runner.route,
        "batch": batch,
        "frames": st.frames,
        "batches": st.batches,
        "padded_frames": st.padded_frames,
        "compile_plus_first_batch_s": round(st.first_batch_s, 3),
        "measured_steady_fps": round(st.steady_fps, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "executables": n_exec,
        "recompiles": (n_exec - 1) if n_exec >= 0 else None,
        "sample_top1": [int(np.asarray(o).reshape(-1).argmax())
                        if output == "logits" else int(o)
                        for o in outs[:4]],
    }
    if eager_frames > 0:
        y = prog.run(stream[:1])           # warm the eager op caches
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for i in range(eager_frames):
            jax.block_until_ready(prog.run(stream[i:i + 1]))
        dt = time.perf_counter() - t0
        result["eager_fps"] = round(eager_frames / dt, 3)
        result["speedup_vs_eager"] = round(
            result["measured_steady_fps"] / max(result["eager_fps"], 1e-9), 2)
    if verbose:
        hw_fps = result["modeled_fps_alg1"]
        print(f"[serve_cnn] {model_name} bits={bits} route={result['route']}"
              f" batch={batch}: measured {result['measured_steady_fps']:.2f}"
              f" fps (steady), modeled {hw_fps:.1f} fps (Alg. 1 @200MHz)"
              f" | first batch {st.first_batch_s:.1f}s"
              f" | recompiles="
              f"{'?' if result['recompiles'] is None else result['recompiles']}")
        if "eager_fps" in result:
            print(f"[serve_cnn]   eager per-sample {result['eager_fps']:.2f}"
                  f" fps -> {result['speedup_vs_eager']:.1f}x batched")
    return result


def _make_executor(prog, *, stages, batch, route, output, place_stages,
                   replicas=1, replica_mode="pipeline", seed=0):
    """One executor for every serve path: the single
    :class:`PipelineExecutor` when ``replicas <= 1`` (exact PR-5
    behaviour), otherwise a :class:`ReplicaPool` of R routed replicas
    over the device mesh (``pipeline``: whole pipeline per device;
    ``stage-shard``: each replica stage-pipelines across its contiguous
    device slice). The router RNG is seeded alongside everything else,
    so cold-start placement replays."""
    from repro.serving import PipelineExecutor, ReplicaPool
    if replicas <= 1:
        return PipelineExecutor(prog, stages=stages, batch_size=batch,
                                route=route, output=output,
                                place_stages=place_stages)
    return ReplicaPool(prog, replicas=replicas, mode=replica_mode,
                       stages=stages, batch_size=batch, route=route,
                       output=output, router_seed=seed)


def _pipeline_throughput(px, stream, batch):
    """Warmup + closed-loop steady-state throughput of one pipeline:
    one micro-batch through all K stages compiles every stage jit (stats
    reset afterwards so the measured window is pure steady state —
    without this, batches queued during the cold compiles flood out the
    moment the pipeline opens and a short stream reads an absurd fps),
    then a saturating closed-loop pass. Returns (warmup_s, phase-1
    stats snapshot) — snapshotting keeps the counts describing exactly
    the window steady_fps was measured over (later frontend phases keep
    accumulating into ``px.stats``). A replica pool warms every replica
    (all R x K stage jits), so no probe ever pays a cold compile
    mid-measurement."""
    t0 = time.perf_counter()
    warm = getattr(px, "warmup", None)
    if warm is not None:
        warm(list(stream[:batch]))
    else:
        px.serve(list(stream[:batch]))
    warmup_s = time.perf_counter() - t0
    # One more single-batch pass through the now-compiled, *empty*
    # pipeline: the unloaded K-stage traversal. This is the honest seed
    # for the admission latency channel — the closed-loop pass below
    # runs saturated, so its per-batch dispatch->done times include
    # stage-queue waits that an admitted open-loop request never sees.
    t0 = time.perf_counter()
    px.serve(list(stream[:batch]))
    lat1_s = time.perf_counter() - t0
    px.reset_stats()
    px.serve(list(stream))
    return warmup_s, lat1_s, dataclasses.replace(px.stats)


def _default_max_wait_ms(batch: int, rate: float) -> float:
    """One full batch assembles in batch/rate seconds; waiting any less
    flushes padded partial batches faster than the pipeline drains them
    (service rate collapses), any more only parks the first frame of a
    quiet period."""
    return 1e3 * batch / rate if rate > 0 else 50.0


def _warmed_frontend(px, steady: float, rate: float, batch: int, *,
                     max_wait_ms: float | None,
                     admission_control: bool,
                     flush_guard_ms: float | None,
                     lat1_s: float | None = None):
    """One convention for the per-replay control plane — shared by the
    QoS rates and the knee probes so their artifacts stay comparable: a
    fresh estimator per replay (an overload replay's noisy tail must
    not skew the next replay's admission), warm-started from the
    measured calibration throughput (:meth:`ServiceTimeEstimator
    .warm_start_channels`) — the window channel at the fleet batch
    window (``batch / steady``), the latency channel at
    ``stages x replicas x window`` (a K-stage traversal is ~K windows,
    and R-way routing multiplies each replica's per-batch beat by R) —
    behind a frontend whose ``max_wait`` defaults to one full-batch
    window at the arrival rate. When the calibration pass measured the
    *unloaded* single-batch traversal (``lat1_s``), that measurement
    replaces the formula on the latency channel: the ``K x R x window``
    bound assumes fleet throughput scales linearly with R, which
    overprices admission whenever replicas share silicon (the backlog
    ahead of a request is priced separately, via the window channel, so
    the latency channel must NOT bake queueing in). With a replica pool
    underneath, the router's per-replica estimators get the matching
    per-replica formula seed — router pricing is relative across
    replicas, so a shared bias cancels — and admission itself stays on
    the fleet numbers: the frontend's shared estimator observes the
    interleaved completion beat of all R replicas."""
    from repro.serving import AsyncFrontend, ServiceTimeEstimator
    n_replicas = getattr(px, "n_replicas", 1)
    warm = batch / max(steady, 1e-9)
    est = ServiceTimeEstimator()
    est.warm_start_channels(batch, warm, stages=px.partition.n_stages,
                            replicas=n_replicas)
    if lat1_s is not None and lat1_s > 0:
        est.warm_start(batch, lat1_s)
    router = getattr(px, "router", None)
    if router is not None:
        router.warm_start(n_replicas * warm,
                          px.partition.n_stages * n_replicas * warm)
    wait_ms = (max_wait_ms if max_wait_ms is not None
               else _default_max_wait_ms(batch, min(rate, steady)))
    return AsyncFrontend(px, max_wait_ms=wait_ms, estimator=est,
                         admission_control=admission_control,
                         flush_guard_ms=flush_guard_ms)


def serve_async(model_name: str, *, frames: int = 64, batch: int = 16,
                stages: int = 2, bits: int = 8, route: str | None = None,
                seed: int = 0, theta: int | None = None,
                max_wait_ms: float | None = None,
                arrival_fps: float | None = None,
                place_stages: bool = False,
                replicas: int = 1, replica_mode: str = "pipeline",
                output: str = "top1", program=None,
                verbose: bool = True) -> dict:
    """Serve ``frames`` synthetic frames through the K-stage pipelined
    subsystem (``repro.serving``) behind the async request frontend.

    Two measurement phases over one compiled pipeline:

    1. **throughput** — closed-loop stream straight into the
       :class:`PipelineExecutor` (saturating, no frontend) after a
       warmup pass, measuring the steady-state FPS the single-jit path's
       ``measured_steady_fps`` is compared against;
    2. **latency** — the :class:`AsyncFrontend` replays the stream as an
       open-loop arrival process at ``arrival_fps`` (default: 70% of the
       measured throughput, scheduled by the shared seeded generator
       :func:`repro.serving.traffic.make_schedule`) and records
       per-request p50/p95/p99. ``max_wait_ms`` defaults to one
       full-batch assembly window at the arrival rate.

    ``place_stages`` pins stage i to ``jax.devices()[i % n]``
    (transparent on a single device); ``replicas > 1`` serves through a
    routed :class:`ReplicaPool` instead. Pass ``program`` to reuse an
    already-compiled program (the bench sweeps stage counts over one
    compile).
    """
    from repro.serving import (AsyncFrontend, TrafficClass, make_schedule,
                               replay)

    if frames <= batch:
        raise ValueError(f"frames={frames} <= batch={batch}: no "
                         f"steady-state window (use frames >= 2*batch)")
    prog = program if program is not None else compile_for_serving(
        model_name, bits=bits, seed=seed, theta=theta)
    stream = synthetic_stream(model_name, frames, seed)

    px = _make_executor(prog, stages=stages, batch=batch, route=route,
                        output=output, place_stages=place_stages,
                        replicas=replicas, replica_mode=replica_mode,
                        seed=seed)
    part = px.partition
    with px:
        warmup_s, lat1_s, ph1 = _pipeline_throughput(px, stream, batch)
        steady = ph1.steady_fps

        # Phase 2: open-loop latency at a sustainable arrival rate, one
        # best-effort class (the QoS path is serve_qos).
        rate = arrival_fps if arrival_fps is not None else 0.7 * steady
        if max_wait_ms is None:
            max_wait_ms = _default_max_wait_ms(batch, rate)
        fe = AsyncFrontend(px, max_wait_ms=max_wait_ms)
        schedule = make_schedule(len(stream), rate,
                                 [TrafficClass("default")], seed=seed)
        replay(fe, stream, schedule)
        fe.close()

    lat = fe.stats.latency_percentiles()
    result = {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_cycles": [round(c, 1) for c in part.stage_cycles],
        "stage_balance": round(part.balance, 4),
        "placed": place_stages,
        "replicas": getattr(px, "n_replicas", 1),
        "replica_mode": replica_mode if replicas > 1 else None,
        "replica_devices": getattr(px, "replica_devices", None),
        "replica_rows": (px.replica_rows()
                         if hasattr(px, "replica_rows") else None),
        "frames": ph1.frames,
        "batches": ph1.batches,
        "padded_frames": ph1.padded_frames,
        "compile_plus_warmup_s": round(warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "arrival_fps": round(rate, 3),
        "client_fps": round(fe.stats.fps, 3),
        "max_wait_ms": round(max_wait_ms, 3),
        "flushes_full": fe.stats.flushes_full,
        "flushes_timeout": fe.stats.flushes_timeout,
        "latency_ms_p50": round(lat["p50"] * 1e3, 3),
        "latency_ms_p95": round(lat["p95"] * 1e3, 3),
        "latency_ms_p99": round(lat["p99"] * 1e3, 3),
        "latency_ms_mean": round(lat["mean"] * 1e3, 3),
    }
    if verbose:
        print(f"[serve_async] {model_name} K={part.n_stages} "
              f"batch={batch}: steady {steady:.2f} fps (balance "
              f"{part.balance:.2f}), arrival {rate:.1f} fps -> p50 "
              f"{result['latency_ms_p50']:.1f}ms p95 "
              f"{result['latency_ms_p95']:.1f}ms p99 "
              f"{result['latency_ms_p99']:.1f}ms | modeled "
              f"{result['modeled_fps_alg1']:.1f} fps")
    return result


def _class_row(cs) -> dict:
    """One traffic class's QoS row: outcome counts, SLO rates, and the
    phase-split latency percentiles (ms)."""
    pp = cs.phase_percentiles()
    return {
        "submitted": cs.submitted,
        "completed": cs.completed,
        "expired": cs.expired,
        "rejected": cs.rejected,
        "rejected_wait": cs.rejected_wait,
        "failed": cs.failed,
        "late": cs.late,
        "drop_rate": round(cs.drop_rate, 4),
        "slo_miss_rate": round(cs.slo_miss_rate, 4),
        "phase_ms": {
            phase: {p: round(v * 1e3, 3) for p, v in pcts.items()}
            for phase, pcts in pp.items()},
    }


def serve_qos(model_name: str, *, frames: int = 96, batch: int = 16,
              stages: int = 2, bits: int = 8, route: str | None = None,
              seed: int = 0, theta: int | None = None,
              slo_ms: float | None = None,
              traffic_mix=None,
              load_factors: tuple[float, ...] = (0.6, 1.2),
              arrival_fps: float | None = None,
              max_wait_ms: float | None = None,
              place_stages: bool = False,
              replicas: int = 1, replica_mode: str = "pipeline",
              poisson: bool = False,
              admission_control: bool = True,
              flush_guard_ms: float | None = None,
              output: str = "top1", program=None,
              verbose: bool = True) -> dict:
    """Serve a mixed-traffic stream through the QoS frontend and report
    per-class phase-split latency, SLO miss rate, and drop rate.

    After the closed-loop throughput phase (shared with
    :func:`serve_async`), each entry of ``load_factors`` replays the
    same seeded mixed-class schedule
    (:func:`repro.serving.traffic.make_schedule`) open-loop at
    ``factor * measured_steady_fps`` — one rate below saturation and one
    above shows the QoS machinery working: under overload the priority
    lanes keep the interactive class inside its deadline while the
    best-effort class absorbs the queueing, and deadline-armed requests
    that cannot make it are dropped (``expired``), not served late.
    ``arrival_fps`` overrides the factor-derived rates with absolute
    rates ``factor * arrival_fps`` instead.

    ``traffic_mix`` is a sequence of :class:`TrafficClass` (default:
    25% interactive priority-1 with deadline ``slo_ms``, 75%
    best-effort batch). A ``slo_ms`` of None is derived from the
    measured service time — ``(stages + 3)`` batch windows at the
    steady rate — so the deadline is feasible below saturation on any
    backend but binds under overload (a fixed wall-clock default would
    be always-missed for a slow model on CPU and never-missed for a
    fast one, telling us nothing).

    The frontend's control decisions are adaptive: each rate's replay
    gets a :class:`~repro.serving.ServiceTimeEstimator` warm-started
    from the measured calibration pass (one batch window at the steady
    rate) and kept current by every completed batch, driving the
    expedited flush; ``admission_control`` (default on) additionally
    refuses deadline-armed requests whose estimated wait already
    exceeds their budget (``rejected_wait`` — they fail fast instead of
    expiring in queue). Set ``admission_control=False`` for the
    estimator-less PR-4 admission behaviour.
    """
    from repro.serving import default_mix, make_schedule, replay

    if frames <= batch:
        raise ValueError(f"frames={frames} <= batch={batch}: no "
                         f"steady-state window (use frames >= 2*batch)")
    prog = program if program is not None else compile_for_serving(
        model_name, bits=bits, seed=seed, theta=theta)
    stream = synthetic_stream(model_name, frames, seed)

    px = _make_executor(prog, stages=stages, batch=batch, route=route,
                        output=output, place_stages=place_stages,
                        replicas=replicas, replica_mode=replica_mode,
                        seed=seed)
    part = px.partition
    rates: dict[str, dict] = {}
    with px:
        warmup_s, lat1_s, ph1 = _pipeline_throughput(px, stream, batch)
        steady = ph1.steady_fps
        base = arrival_fps if arrival_fps is not None else steady
        if slo_ms is None:
            # A request's best case traverses assembly (~1 window) plus
            # the K-stage pipeline with its depth-2 queues; ~stages + 3
            # windows is comfortably feasible below saturation. With R
            # routed replicas the *fleet* window is ~R x shorter than
            # one replica's per-batch beat, but a batch still traverses
            # a single replica — so the traversal term scales by R.
            slo_ms = round(
                (part.n_stages * getattr(px, "n_replicas", 1) + 3)
                * 1e3 * batch / max(steady, 1e-9), 1)
        mix = tuple(traffic_mix) if traffic_mix is not None \
            else default_mix(slo_ms)

        warm_start_s = batch / max(steady, 1e-9)
        for factor in load_factors:
            rate = factor * base
            fe = _warmed_frontend(px, steady, rate, batch,
                                  max_wait_ms=max_wait_ms,
                                  admission_control=admission_control,
                                  flush_guard_ms=flush_guard_ms,
                                  lat1_s=lat1_s)
            schedule = make_schedule(len(stream), rate, mix, seed=seed,
                                     poisson=poisson)
            replay(fe, stream, schedule)
            fe.close()
            st = fe.stats
            rates[f"{factor:g}x"] = {
                "load_factor": factor,
                "arrival_fps": round(rate, 3),
                "client_fps": round(st.fps, 3),
                "max_wait_ms": round(fe.max_wait_s * 1e3, 3),
                "submitted": st.submitted,
                "completed": st.completed,
                "expired": st.expired,
                "rejected": st.rejected,
                "rejected_wait": st.rejected_wait,
                "failed": st.failed,
                "batches": st.batches,
                "flushes_full": st.flushes_full,
                "flushes_timeout": st.flushes_timeout,
                "flushes_deadline": st.flushes_deadline,
                "control": fe.control_config(),
                "classes": {name: _class_row(cs)
                            for name, cs in sorted(st.classes.items())},
                "replica_outcomes": st.replicas or None,
            }
            if verbose:
                parts = []
                for name, cs in sorted(st.classes.items()):
                    pq = cs.phase_percentiles()
                    parts.append(
                        f"{name}: p95 q/a/c "
                        f"{pq['queueing']['p95'] * 1e3:.1f}/"
                        f"{pq['assembly']['p95'] * 1e3:.1f}/"
                        f"{pq['compute']['p95'] * 1e3:.1f}ms "
                        f"miss {cs.slo_miss_rate:.0%} "
                        f"drop {cs.drop_rate:.0%}")
                print(f"[serve_qos] {model_name} K={part.n_stages} "
                      f"load {factor:g}x ({rate:.1f} fps): "
                      + " | ".join(parts))

    return {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_balance": round(part.balance, 4),
        "placed": place_stages,
        "stage_devices": ([str(d) for d in px.stage_devices]
                          if place_stages and hasattr(px, "stage_devices")
                          else None),
        "replicas": getattr(px, "n_replicas", 1),
        "replica_mode": replica_mode if replicas > 1 else None,
        "replica_devices": getattr(px, "replica_devices", None),
        "replica_rows": (px.replica_rows()
                         if hasattr(px, "replica_rows") else None),
        "seed": seed,
        "slo_ms": slo_ms,
        "poisson": poisson,
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "estimator_warm_start_ms": round(1e3 * warm_start_s, 3),
        "traffic_mix": [c.to_json() for c in mix],
        "frames": frames,
        "compile_plus_warmup_s": round(warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "rates": rates,
    }


def serve_knee(model_name: str, *, frames: int = 96, batch: int = 16,
               stages: int = 2, bits: int = 8, route: str | None = None,
               seed: int = 0, theta: int | None = None,
               slo_ms: float | None = None,
               traffic_mix=None,
               miss_target: float = 0.01,
               start_factor: float = 0.5,
               start_qps: float | None = None,
               max_factor: float = 4.0,
               refine_iters: int = 3,
               max_wait_ms: float | None = None,
               flush_guard_ms: float | None = None,
               admission_control: bool = True,
               place_stages: bool = False,
               replicas: int = 1, replica_mode: str = "pipeline",
               poisson: bool = False,
               output: str = "top1", program=None,
               verbose: bool = True) -> dict:
    """Bracketing absolute-QPS sweep: find the knee — the maximum
    sustained arrival rate at which the deadline-armed (interactive)
    classes keep ``slo_miss_rate < miss_target`` — and record it as the
    headline capacity number.

    ``serve_qos`` reports behaviour at load factors *relative to* the
    measured steady fps; the knee is the *absolute* QPS answer to "how
    much traffic can this deployment take": replay the seeded mix
    open-loop at ``start_factor * steady`` QPS, double while the armed
    classes stay under ``miss_target`` (capped at ``max_factor *
    steady``), halve downward if even the first probe misses, then
    bisect the sustained/unsustained bracket ``refine_iters`` times.
    Every probe reuses the same compiled pipeline, the same seeded
    schedule generator, and a fresh estimator warm-started from the
    calibration pass, so the sweep is reproducible from the recorded
    ``(seed, mix, rates)`` alone. A miss at any probe counts every
    armed-class request that did not complete inside its deadline —
    expired + refused at admission (``rejected_wait``, or ``rejected``
    on a full lane) + served late — so failing fast cannot launder the
    miss rate.

    ``replicas > 1`` sweeps the same knee over a routed
    :class:`ReplicaPool`; ``start_qps`` opens the bracket at an absolute
    rate instead of ``start_factor * steady`` — the knee-vs-R scaling
    sweep starts each R>1 bracket at the R=1 knee, so "replication never
    loses to one replica" is probed directly.
    """
    from repro.serving import (armed_class_names, default_mix,
                               make_schedule, replay)

    if frames <= batch:
        raise ValueError(f"frames={frames} <= batch={batch}: no "
                         f"steady-state window (use frames >= 2*batch)")
    if not 0.0 < miss_target < 1.0:
        raise ValueError(f"miss_target={miss_target} not in (0, 1)")
    prog = program if program is not None else compile_for_serving(
        model_name, bits=bits, seed=seed, theta=theta)
    stream = synthetic_stream(model_name, frames, seed)

    px = _make_executor(prog, stages=stages, batch=batch, route=route,
                        output=output, place_stages=place_stages,
                        replicas=replicas, replica_mode=replica_mode,
                        seed=seed)
    part = px.partition
    probes: list[dict] = []
    with px:
        warmup_s, lat1_s, ph1 = _pipeline_throughput(px, stream, batch)
        steady = ph1.steady_fps
        if slo_ms is None:
            # Same budget convention as serve_qos: traversal is through
            # ONE replica, so the term scales by R even though the fleet
            # window (batch / steady) shrinks with R.
            slo_ms = round(
                (part.n_stages * getattr(px, "n_replicas", 1) + 3)
                * 1e3 * batch / max(steady, 1e-9), 1)
        mix = tuple(traffic_mix) if traffic_mix is not None \
            else default_mix(slo_ms)
        armed = armed_class_names(mix)
        if not armed:
            raise ValueError("traffic mix has no deadline-armed class — "
                             "nothing can define 'sustained'")
        warm_start_s = batch / max(steady, 1e-9)

        def _probe(rate: float) -> dict:
            fe = _warmed_frontend(px, steady, rate, batch,
                                  max_wait_ms=max_wait_ms,
                                  admission_control=admission_control,
                                  flush_guard_ms=flush_guard_ms,
                                  lat1_s=lat1_s)
            schedule = make_schedule(len(stream), rate, mix, seed=seed,
                                     poisson=poisson)
            replay(fe, stream, schedule)
            fe.close()
            st = fe.stats
            cls = [st.klass(n) for n in armed if n in st.classes]
            n_armed = sum(c.submitted for c in cls)
            n_miss = sum(c.expired + c.rejected + c.rejected_wait + c.late
                         for c in cls)
            # The verdict is computed on the rounded rate the artifact
            # stores, so `sustained` and `armed_miss_rate` can never
            # contradict each other under the validator's cross-check.
            miss = round(n_miss / n_armed if n_armed else 0.0, 4)
            total_s = [s for c in cls for s in c.total_s]
            # None, not NaN, when no armed request completed — NaN is
            # not valid JSON and would poison the uploaded artifact.
            p95_ms = (round(float(np.percentile(np.asarray(total_s), 95))
                            * 1e3, 3) if total_s else None)
            row = {
                "arrival_fps": round(rate, 3),
                "sustained": bool(miss < miss_target),
                "armed_miss_rate": miss,
                "armed_submitted": n_armed,
                "armed_missed": n_miss,
                "armed_p95_ms": p95_ms,
                "client_fps": round(st.fps, 3),
                "max_wait_ms": round(fe.max_wait_s * 1e3, 3),
                "submitted": st.submitted,
                "completed": st.completed,
                "expired": st.expired,
                "rejected": st.rejected,
                "rejected_wait": st.rejected_wait,
                "failed": st.failed,
            }
            if verbose:
                print(f"[serve_knee] {model_name} probe {rate:8.2f} qps: "
                      f"armed miss {miss:6.2%} "
                      f"({'sustained' if row['sustained'] else 'MISS'}) | "
                      f"expired {st.expired} rejected_wait "
                      f"{st.rejected_wait} p95 "
                      + (f"{p95_ms:.1f}ms" if p95_ms is not None else "n/a"))
            return row

        # Bracket: escalate from start_factor * steady (or the absolute
        # start_qps) by doubling until the armed miss rate crosses the
        # target (or the cap), then bisect [highest sustained, lowest
        # unsustained].
        cap = max(max_factor * steady,
                  start_qps if start_qps is not None else 0.0)
        lo_rate, lo_row, hi_rate = None, None, None
        rate = start_qps if start_qps is not None else start_factor * steady
        while hi_rate is None:
            row = _probe(rate)
            probes.append(row)
            if row["sustained"]:
                lo_rate, lo_row = rate, row
                if rate >= cap:
                    break
                rate = min(2 * rate, cap)
            else:
                hi_rate = rate
        if lo_rate is None:
            # Even the opening probe missed: descend until sustained or
            # the sweep floor — a knee of None means this deployment
            # cannot hold the SLO at any probed rate.
            floor = 0.05 * steady
            while lo_rate is None and rate / 2 >= floor:
                rate = rate / 2
                row = _probe(rate)
                probes.append(row)
                if row["sustained"]:
                    lo_rate, lo_row = rate, row
                else:
                    hi_rate = rate
        for _ in range(max(0, int(refine_iters))):
            if lo_rate is None or hi_rate is None:
                break
            if hi_rate / lo_rate < 1.05:
                break
            mid = (lo_rate + hi_rate) / 2
            row = _probe(mid)
            probes.append(row)
            if row["sustained"]:
                lo_rate, lo_row = mid, row
            else:
                hi_rate = mid

    result = {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_balance": round(part.balance, 4),
        "placed": place_stages,
        "replicas": getattr(px, "n_replicas", 1),
        "replica_mode": replica_mode if replicas > 1 else None,
        "replica_devices": getattr(px, "replica_devices", None),
        "replica_rows": (px.replica_rows()
                         if hasattr(px, "replica_rows") else None),
        "start_qps": None if start_qps is None else round(start_qps, 3),
        "seed": seed,
        "slo_ms": slo_ms,
        "poisson": poisson,
        "miss_target": miss_target,
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "estimator_warm_start_ms": round(1e3 * warm_start_s, 3),
        "traffic_mix": [c.to_json() for c in mix],
        "frames": frames,
        "compile_plus_warmup_s": round(warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "knee_qps": None if lo_rate is None else round(lo_rate, 3),
        "knee_of_steady": (None if lo_rate is None
                           else round(lo_rate / max(steady, 1e-9), 4)),
        "knee_miss_rate": (None if lo_row is None
                           else lo_row["armed_miss_rate"]),
        "knee_armed_p95_ms": (None if lo_row is None
                              else lo_row["armed_p95_ms"]),
        "bracket_unsustained_qps": (None if hi_rate is None
                                    else round(hi_rate, 3)),
        "probes": probes,
    }
    if verbose:
        knee = result["knee_qps"]
        print(f"[serve_knee] {model_name} K={part.n_stages} batch={batch}: "
              f"knee "
              + (f"{knee:.1f} qps ({result['knee_of_steady']:.2f}x steady)"
                 if knee is not None else "not found")
              + f" at armed miss < {miss_target:.0%} | steady "
              f"{steady:.1f} fps | slo {slo_ms:.0f}ms | "
              f"{len(probes)} probes")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="alexnet",
                    choices=sorted(W.CNN_MODELS))
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--bits", type=int, default=8, choices=(8, 16))
    ap.add_argument("--route", default=None,
                    choices=("f32", "oracle", "kernel"),
                    help="MAC lowering (default: f32 for int8)")
    ap.add_argument("--eager-frames", type=int, default=0,
                    help="also time N frames through the eager loop")
    ap.add_argument("--output", default="top1",
                    choices=("top1", "logits"))
    ap.add_argument("--stages", type=int, default=0,
                    help="serve through the K-stage pipelined subsystem "
                         "with the async frontend (0 = single-jit path)")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="dynamic batcher flush timeout (async path; "
                         "default: one full-batch window at the arrival "
                         "rate)")
    ap.add_argument("--arrival-fps", type=float, default=None,
                    help="open-loop request rate (default: 70%% of the "
                         "measured pipeline throughput)")
    ap.add_argument("--place-stages", action="store_true",
                    help="pin stage i to jax.devices()[i %% n] "
                         "(transparent on a single device)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through R routed pipeline replicas "
                         "(ReplicaPool + least-estimated-wait router; "
                         "implies the pipelined subsystem)")
    ap.add_argument("--replica-mode", default="pipeline",
                    choices=("pipeline", "stage-shard"),
                    help="replica placement: whole pipeline per device, "
                         "or stages sharded across each replica's "
                         "contiguous device slice")
    ap.add_argument("--qos", action="store_true",
                    help="serve a mixed-traffic stream through the QoS "
                         "frontend (priority lanes + deadlines) and "
                         "report per-class phase-split latency")
    ap.add_argument("--knee", action="store_true",
                    help="bracketing absolute-QPS sweep: report the max "
                         "sustained rate with interactive miss rate "
                         "under --miss-target (the capacity knee)")
    ap.add_argument("--miss-target", type=float, default=0.01,
                    help="armed-class SLO miss rate defining 'sustained' "
                         "for --knee (default 0.01)")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable estimated-wait admission control "
                         "(PR-4 lane-bound-only admission)")
    ap.add_argument("--flush-guard-ms", type=float, default=None,
                    help="fixed expedited-flush guard margin (default: "
                         "adaptive, 25%% of the service estimate + 2ms)")
    ap.add_argument("--traffic-mix", default=None,
                    help="QoS mix as name:priority:share[:deadline_ms] "
                         "comma-separated ('slo' = --slo-ms; default: "
                         "interactive:1:0.25:slo,batch:0:0.75)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="deadline for the default interactive class "
                         "(implies --qos)")
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream RNG seed")
    ap.add_argument("--quick", action="store_true",
                    help="small smoke setting (8 frames, batch 4)")
    args = ap.parse_args(argv)
    if args.quick:
        args.frames, args.batch = 8, 4
    qos = args.qos or args.traffic_mix is not None or args.slo_ms is not None
    if args.knee or qos:
        from repro.serving import parse_traffic_mix
        # slo_ms=None lets serve_qos derive a feasible deadline from
        # the measured service time; only an explicit --slo-ms pins it
        # (and is required when --traffic-mix uses the 'slo' token).
        mix = (parse_traffic_mix(args.traffic_mix, args.slo_ms)
               if args.traffic_mix else None)
    if args.knee:
        serve_knee(args.model, frames=args.frames, batch=args.batch,
                   stages=max(args.stages, 1), bits=args.bits,
                   route=args.route, seed=args.seed, slo_ms=args.slo_ms,
                   traffic_mix=mix, miss_target=args.miss_target,
                   max_wait_ms=args.max_wait_ms,
                   flush_guard_ms=args.flush_guard_ms,
                   admission_control=not args.no_admission,
                   place_stages=args.place_stages,
                   replicas=args.replicas,
                   replica_mode=args.replica_mode, output=args.output)
    elif qos:
        serve_qos(args.model, frames=args.frames, batch=args.batch,
                  stages=max(args.stages, 1), bits=args.bits,
                  route=args.route, seed=args.seed, slo_ms=args.slo_ms,
                  traffic_mix=mix, arrival_fps=args.arrival_fps,
                  max_wait_ms=args.max_wait_ms,
                  admission_control=not args.no_admission,
                  flush_guard_ms=args.flush_guard_ms,
                  place_stages=args.place_stages,
                  replicas=args.replicas,
                  replica_mode=args.replica_mode, output=args.output)
    elif args.stages > 0 or args.replicas > 1:
        serve_async(args.model, frames=args.frames, batch=args.batch,
                    stages=max(args.stages, 1), bits=args.bits,
                    route=args.route, max_wait_ms=args.max_wait_ms,
                    arrival_fps=args.arrival_fps, output=args.output,
                    place_stages=args.place_stages,
                    replicas=args.replicas,
                    replica_mode=args.replica_mode, seed=args.seed)
    else:
        serve(args.model, frames=args.frames, batch=args.batch,
              bits=args.bits, route=args.route, seed=args.seed,
              eager_frames=args.eager_frames, output=args.output)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
