"""CNN serving launcher: stream frames through a compiled EngineProgram.

Serves any of the four paper models (vgg16 / alexnet / zf / yolo) from a
single jitted step chain via :class:`repro.core.executor.EngineExecutor`
and reports measured steady-state FPS next to the Algorithm-1 predicted
FPS of the same plan (the paper's modeled pipeline throughput on the
ZC706-class budget).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import workload as W
from repro.core.executor import EngineExecutor
from repro.core.program import compile_model
from repro.models import cnn


def serve(model_name: str, *, frames: int = 64, batch: int = 16,
          bits: int = 8, route: str | None = None, seed: int = 0,
          theta: int | None = None, eager_frames: int = 0,
          output: str = "top1", verbose: bool = True) -> dict:
    """Compile ``model_name``, serve ``frames`` synthetic frames, return a
    result dict (measured/modeled FPS). ``eager_frames > 0`` also times
    the eager per-sample reference loop for comparison."""
    if frames <= batch:
        raise ValueError(
            f"frames={frames} <= batch={batch}: the whole stream fits in "
            f"the first micro-batch, which is charged to compile/warmup, "
            f"leaving no steady-state window to measure (steady_fps would "
            f"be 0). Use frames >= 2*batch.")
    m = W.CNN_MODELS[model_name]()
    params = cnn.init_params(m, jax.random.PRNGKey(seed))
    calib = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (1, m.input_hw, m.input_hw,
                                       m.input_ch))
    # The plan only affects the modeled numbers, never the executed
    # arithmetic — use Table I's budget convention for the bit width
    # (8-bit double-pumps the 900 DSPs) so modeled_fps_alg1 here equals
    # the fps8/fps16 column in benchmarks/table1.py.
    if theta is None:
        theta = 2 * 900 - len(m.layers) if bits == 8 else 900
    kwargs = {"theta": theta,
              "bram_total": None if bits == 8 else 545}
    prog = compile_model(m, params, bits=bits, calib_batch=calib, **kwargs)

    rng = np.random.default_rng(seed + 2)
    stream = rng.standard_normal(
        (frames, m.input_hw, m.input_hw, m.input_ch), dtype=np.float32)

    ex = EngineExecutor(prog, batch_size=batch, route=route, output=output)
    outs = ex.serve(stream)
    st = ex.stats

    # cache_size() counts XLA executables (1 = compiled once, never
    # recompiled); -1 means the running jax doesn't expose the counter.
    n_exec = ex.runner.cache_size()
    result = {
        "model": model_name,
        "bits": bits,
        "route": ex.runner.route,
        "batch": batch,
        "frames": st.frames,
        "batches": st.batches,
        "padded_frames": st.padded_frames,
        "compile_plus_first_batch_s": round(st.first_batch_s, 3),
        "measured_steady_fps": round(st.steady_fps, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "executables": n_exec,
        "recompiles": (n_exec - 1) if n_exec >= 0 else None,
        "sample_top1": [int(np.asarray(o).reshape(-1).argmax())
                        if output == "logits" else int(o)
                        for o in outs[:4]],
    }
    if eager_frames > 0:
        y = prog.run(stream[:1])           # warm the eager op caches
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for i in range(eager_frames):
            jax.block_until_ready(prog.run(stream[i:i + 1]))
        dt = time.perf_counter() - t0
        result["eager_fps"] = round(eager_frames / dt, 3)
        result["speedup_vs_eager"] = round(
            result["measured_steady_fps"] / max(result["eager_fps"], 1e-9), 2)
    if verbose:
        hw_fps = result["modeled_fps_alg1"]
        print(f"[serve_cnn] {model_name} bits={bits} route={result['route']}"
              f" batch={batch}: measured {result['measured_steady_fps']:.2f}"
              f" fps (steady), modeled {hw_fps:.1f} fps (Alg. 1 @200MHz)"
              f" | first batch {st.first_batch_s:.1f}s"
              f" | recompiles="
              f"{'?' if result['recompiles'] is None else result['recompiles']}")
        if "eager_fps" in result:
            print(f"[serve_cnn]   eager per-sample {result['eager_fps']:.2f}"
                  f" fps -> {result['speedup_vs_eager']:.1f}x batched")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="alexnet",
                    choices=sorted(W.CNN_MODELS))
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--bits", type=int, default=8, choices=(8, 16))
    ap.add_argument("--route", default=None,
                    choices=("f32", "oracle", "kernel"),
                    help="MAC lowering (default: f32 for int8)")
    ap.add_argument("--eager-frames", type=int, default=0,
                    help="also time N frames through the eager loop")
    ap.add_argument("--output", default="top1",
                    choices=("top1", "logits"))
    ap.add_argument("--quick", action="store_true",
                    help="small smoke setting (8 frames, batch 4)")
    args = ap.parse_args(argv)
    if args.quick:
        args.frames, args.batch = 8, 4
    serve(args.model, frames=args.frames, batch=args.batch, bits=args.bits,
          route=args.route, eager_frames=args.eager_frames,
          output=args.output)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
