"""CNN serving launcher: stream frames through a compiled EngineProgram.

Serves any of the four paper models (vgg16 / alexnet / zf / yolo) either
from a single jitted step chain (:class:`repro.core.executor
.EngineExecutor`) or through the stage-pipelined serving subsystem
(``--stages K``: :class:`repro.serving.PipelineExecutor` + the async
:class:`repro.serving.AsyncFrontend`), reporting measured steady-state
FPS next to the Algorithm-1 predicted FPS of the same plan (the paper's
modeled pipeline throughput on the ZC706-class budget) — plus request
latency percentiles for the async path.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16
  PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet \
      --frames 64 --batch 16 --stages 2 --max-wait-ms 10
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core import workload as W
from repro.core.executor import EngineExecutor
from repro.core.program import compile_model
from repro.models import cnn


def compile_for_serving(model_name: str, *, bits: int = 8, seed: int = 0,
                        theta: int | None = None):
    """Compile ``model_name`` exactly as the serve paths consume it:
    seeded params, seeded calibration batch, Table I's budget convention
    for the bit width (the plan only affects modeled numbers — never the
    executed arithmetic)."""
    m = W.CNN_MODELS[model_name]()
    params = cnn.init_params(m, jax.random.PRNGKey(seed))
    calib = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (1, m.input_hw, m.input_hw,
                                       m.input_ch))
    # 8-bit double-pumps the 900 DSPs, so modeled_fps_alg1 here equals
    # the fps8/fps16 column in benchmarks/table1.py.
    if theta is None:
        theta = 2 * 900 - len(m.layers) if bits == 8 else 900
    kwargs = {"theta": theta,
              "bram_total": None if bits == 8 else 545}
    return compile_model(m, params, bits=bits, calib_batch=calib, **kwargs)


def synthetic_stream(model_name: str, frames: int,
                     seed: int = 0) -> np.ndarray:
    """The seeded synthetic frame stream every serve/bench entry point
    shares (explicit RNG: identical frames run to run)."""
    m = W.CNN_MODELS[model_name]()
    rng = np.random.default_rng(seed + 2)
    return rng.standard_normal(
        (frames, m.input_hw, m.input_hw, m.input_ch), dtype=np.float32)


def serve(model_name: str, *, frames: int = 64, batch: int = 16,
          bits: int = 8, route: str | None = None, seed: int = 0,
          theta: int | None = None, eager_frames: int = 0,
          output: str = "top1", verbose: bool = True) -> dict:
    """Compile ``model_name``, serve ``frames`` synthetic frames, return a
    result dict (measured/modeled FPS). ``eager_frames > 0`` also times
    the eager per-sample reference loop for comparison."""
    if frames <= batch:
        raise ValueError(
            f"frames={frames} <= batch={batch}: the whole stream fits in "
            f"the first micro-batch, which is charged to compile/warmup, "
            f"leaving no steady-state window to measure (steady_fps would "
            f"be 0). Use frames >= 2*batch.")
    prog = compile_for_serving(model_name, bits=bits, seed=seed, theta=theta)
    stream = synthetic_stream(model_name, frames, seed)

    ex = EngineExecutor(prog, batch_size=batch, route=route, output=output)
    outs = ex.serve(stream)
    st = ex.stats

    # cache_size() counts XLA executables (1 = compiled once, never
    # recompiled); -1 means the running jax doesn't expose the counter.
    n_exec = ex.runner.cache_size()
    result = {
        "model": model_name,
        "bits": bits,
        "route": ex.runner.route,
        "batch": batch,
        "frames": st.frames,
        "batches": st.batches,
        "padded_frames": st.padded_frames,
        "compile_plus_first_batch_s": round(st.first_batch_s, 3),
        "measured_steady_fps": round(st.steady_fps, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "executables": n_exec,
        "recompiles": (n_exec - 1) if n_exec >= 0 else None,
        "sample_top1": [int(np.asarray(o).reshape(-1).argmax())
                        if output == "logits" else int(o)
                        for o in outs[:4]],
    }
    if eager_frames > 0:
        y = prog.run(stream[:1])           # warm the eager op caches
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for i in range(eager_frames):
            jax.block_until_ready(prog.run(stream[i:i + 1]))
        dt = time.perf_counter() - t0
        result["eager_fps"] = round(eager_frames / dt, 3)
        result["speedup_vs_eager"] = round(
            result["measured_steady_fps"] / max(result["eager_fps"], 1e-9), 2)
    if verbose:
        hw_fps = result["modeled_fps_alg1"]
        print(f"[serve_cnn] {model_name} bits={bits} route={result['route']}"
              f" batch={batch}: measured {result['measured_steady_fps']:.2f}"
              f" fps (steady), modeled {hw_fps:.1f} fps (Alg. 1 @200MHz)"
              f" | first batch {st.first_batch_s:.1f}s"
              f" | recompiles="
              f"{'?' if result['recompiles'] is None else result['recompiles']}")
        if "eager_fps" in result:
            print(f"[serve_cnn]   eager per-sample {result['eager_fps']:.2f}"
                  f" fps -> {result['speedup_vs_eager']:.1f}x batched")
    return result


def serve_async(model_name: str, *, frames: int = 64, batch: int = 16,
                stages: int = 2, bits: int = 8, route: str | None = None,
                seed: int = 0, theta: int | None = None,
                max_wait_ms: float | None = None,
                arrival_fps: float | None = None,
                output: str = "top1", program=None,
                verbose: bool = True) -> dict:
    """Serve ``frames`` synthetic frames through the K-stage pipelined
    subsystem (``repro.serving``) behind the async request frontend.

    Two measurement phases over one compiled pipeline:

    1. **throughput** — after a warmup batch compiles every stage jit
       (stats reset so the window is pure steady state), a closed-loop
       stream straight into the :class:`PipelineExecutor` (saturating,
       no frontend) measures steady-state FPS, the number the single-jit
       path's ``measured_steady_fps`` is compared against;
    2. **latency** — the :class:`AsyncFrontend` replays the stream as an
       open-loop arrival process at ``arrival_fps`` (default: 70% of the
       measured throughput) and records per-request p50/p95/p99.
       ``max_wait_ms`` defaults to one full-batch assembly window at the
       arrival rate (``batch / arrival_fps``), so the dynamic batcher
       neither thrashes on padded 1-frame batches nor parks lone frames.

    Pass ``program`` to reuse an already-compiled program (the bench
    sweeps stage counts over one compile).
    """
    from repro.serving import AsyncFrontend, PipelineExecutor

    if frames <= batch:
        raise ValueError(f"frames={frames} <= batch={batch}: no "
                         f"steady-state window (use frames >= 2*batch)")
    prog = program if program is not None else compile_for_serving(
        model_name, bits=bits, seed=seed, theta=theta)
    stream = synthetic_stream(model_name, frames, seed)

    px = PipelineExecutor(prog, stages=stages, batch_size=batch,
                          route=route, output=output)
    part = px.partition
    with px:
        # Warmup: one micro-batch through all K stages compiles every
        # stage jit. Resetting afterwards makes the measured window pure
        # steady state — without this, batches queued during the cold
        # compiles flood out the moment the pipeline opens and a short
        # stream reads an absurd fps.
        t0 = time.perf_counter()
        px.serve(list(stream[:batch]))
        warmup_s = time.perf_counter() - t0
        px.reset_stats()

        # Phase 1: closed-loop throughput (hot jits, every frame counts).
        px.serve(list(stream))
        # Snapshot before phase 2 keeps these counts describing exactly
        # the window steady_fps was measured over (the frontend phase
        # keeps accumulating into px.stats).
        ph1 = dataclasses.replace(px.stats)
        steady = ph1.steady_fps

        # Phase 2: open-loop latency at a sustainable arrival rate.
        rate = arrival_fps if arrival_fps is not None else 0.7 * steady
        if max_wait_ms is None:
            # One full batch assembles in batch/rate seconds; waiting any
            # less flushes padded partial batches faster than the
            # pipeline drains them (service rate collapses), any more
            # only parks the first frame of a quiet period.
            max_wait_ms = 1e3 * batch / rate if rate > 0 else 50.0
        fe = AsyncFrontend(px, max_wait_ms=max_wait_ms)
        period = 1.0 / rate if rate > 0 else 0.0
        t_next = time.perf_counter()
        reqs = []
        for f in stream:
            if period:
                delay = t_next - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                t_next += period
            reqs.append(fe.submit(f))
        for r in reqs:
            r.result(timeout=600)
        fe.close()

    lat = fe.stats.latency_percentiles()
    result = {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_cycles": [round(c, 1) for c in part.stage_cycles],
        "stage_balance": round(part.balance, 4),
        "frames": ph1.frames,
        "batches": ph1.batches,
        "padded_frames": ph1.padded_frames,
        "compile_plus_warmup_s": round(warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "arrival_fps": round(rate, 3),
        "client_fps": round(fe.stats.fps, 3),
        "max_wait_ms": round(max_wait_ms, 3),
        "flushes_full": fe.stats.flushes_full,
        "flushes_timeout": fe.stats.flushes_timeout,
        "latency_ms_p50": round(lat["p50"] * 1e3, 3),
        "latency_ms_p95": round(lat["p95"] * 1e3, 3),
        "latency_ms_p99": round(lat["p99"] * 1e3, 3),
        "latency_ms_mean": round(lat["mean"] * 1e3, 3),
    }
    if verbose:
        print(f"[serve_async] {model_name} K={part.n_stages} "
              f"batch={batch}: steady {steady:.2f} fps (balance "
              f"{part.balance:.2f}), arrival {rate:.1f} fps -> p50 "
              f"{result['latency_ms_p50']:.1f}ms p95 "
              f"{result['latency_ms_p95']:.1f}ms p99 "
              f"{result['latency_ms_p99']:.1f}ms | modeled "
              f"{result['modeled_fps_alg1']:.1f} fps")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="alexnet",
                    choices=sorted(W.CNN_MODELS))
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--bits", type=int, default=8, choices=(8, 16))
    ap.add_argument("--route", default=None,
                    choices=("f32", "oracle", "kernel"),
                    help="MAC lowering (default: f32 for int8)")
    ap.add_argument("--eager-frames", type=int, default=0,
                    help="also time N frames through the eager loop")
    ap.add_argument("--output", default="top1",
                    choices=("top1", "logits"))
    ap.add_argument("--stages", type=int, default=0,
                    help="serve through the K-stage pipelined subsystem "
                         "with the async frontend (0 = single-jit path)")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="dynamic batcher flush timeout (async path; "
                         "default: one full-batch window at the arrival "
                         "rate)")
    ap.add_argument("--arrival-fps", type=float, default=None,
                    help="open-loop request rate (default: 70%% of the "
                         "measured pipeline throughput)")
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream RNG seed")
    ap.add_argument("--quick", action="store_true",
                    help="small smoke setting (8 frames, batch 4)")
    args = ap.parse_args(argv)
    if args.quick:
        args.frames, args.batch = 8, 4
    if args.stages > 0:
        serve_async(args.model, frames=args.frames, batch=args.batch,
                    stages=args.stages, bits=args.bits, route=args.route,
                    max_wait_ms=args.max_wait_ms,
                    arrival_fps=args.arrival_fps, output=args.output,
                    seed=args.seed)
    else:
        serve(args.model, frames=args.frames, batch=args.batch,
              bits=args.bits, route=args.route, seed=args.seed,
              eager_frames=args.eager_frames, output=args.output)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
