"""The assigned input-shape set and ShapeDtypeStruct stand-ins.

Four shapes per LM architecture (40 cells):
  train_4k     seq 4096  x global_batch 256   (training, train_step)
  prefill_32k  seq 32768 x global_batch 32    (inference prefill)
  decode_32k   one token against a 32768 KV cache, global_batch 128
  long_500k    one token against a 524288-token context, global_batch 1
               (sub-quadratic archs only: recurrentgemma-2b, rwkv6-7b)

``input_specs`` returns ShapeDtypeStructs only — weak-type-correct,
shardable, no allocation — which is what dryrun.py lowers against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode


SHAPES: dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k runs only for sub-quadratic archs (see DESIGN.md)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 524288-ctx decode skipped"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, case: ShapeCase) -> dict:
    """Model inputs for the given shape case, as ShapeDtypeStructs."""
    B, S = case.global_batch, case.seq_len
    bf16, i32 = jnp.bfloat16, jnp.int32
    if case.mode == "train":
        if cfg.family == "enc_dec":
            return {"enc_embeds": _sds((B, S, cfg.d_model), bf16),
                    "tokens": _sds((B, S), i32),
                    "labels": _sds((B, S), i32)}
        if cfg.frontend_stub:  # vlm: precomputed patch embeddings + M-RoPE
            return {"embeds": _sds((B, S, cfg.d_model), bf16),
                    "positions": _sds((B, S, 3), i32),
                    "labels": _sds((B, S), i32)}
        return {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}
    if case.mode == "prefill":
        if cfg.family == "enc_dec":
            return {"enc_embeds": _sds((B, S, cfg.d_model), bf16),
                    "tokens": _sds((B, S), i32)}
        if cfg.frontend_stub:
            return {"embeds": _sds((B, S, cfg.d_model), bf16),
                    "positions": _sds((B, S, 3), i32)}
        return {"tokens": _sds((B, S), i32)}
    # decode: one new token against a cache of case.seq_len
    if cfg.frontend_stub and cfg.family != "enc_dec":
        return {"embeds": _sds((B, 1, cfg.d_model), bf16),
                "positions": _sds((B, 1, 3), i32)}
    return {"tokens": _sds((B, 1), i32)}


def cache_specs(cfg: ModelConfig, case: ShapeCase) -> dict:
    """Abstract KV/state cache for decode shapes."""
    from repro.models import transformer as T
    B = case.global_batch

    def make():
        return T.init_cache(cfg, B, case.seq_len)

    return jax.eval_shape(make)
