"""HLO text statistics: per-collective operand byte accounting.

Separate from dryrun.py so tests and benchmarks can import it without
triggering dryrun's 512-device XLA_FLAGS (which must be set before any
jax import and therefore lives on dryrun's first lines).
"""

from __future__ import annotations

import re

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_DEF_RE = re.compile(r"%([\w.\-]+) = ([a-z]+[0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (SPMD-partitioned)
    HLO. Sizes are per-device; multiply by device count for fabric-total."""
    sizes: dict[str, int] = {}
    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if m:
            name, dt, dims = m.groups()
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            sizes[name] = n * _DTYPE_BYTES.get(dt, 4)
        cm = _COLL_RE.search(line)
        if cm and "=" in line and not line.strip().startswith("//"):
            kind = cm.group(1)
            if f" {kind}(" not in line and f"{kind}-start(" not in line:
                continue
            ops = re.findall(r"\(([^)]*)\)", line)
            total = 0
            if ops:
                for ref in re.findall(r"%([\w.\-]+)", ops[0]):
                    total += sizes.get(ref, 0)
            if total == 0 and m:
                total = sizes.get(m.group(1), 0)
            per_kind[kind] = per_kind.get(kind, 0) + total
            count[kind] = count.get(kind, 0) + 1
    return {"bytes_per_kind": per_kind, "count_per_kind": count,
            "total_bytes": sum(per_kind.values())}


