"""Training launcher.

Small-scale real execution on whatever devices exist (CPU smoke / a TPU
slice), with the full substrate: sharded data pipeline, AdamW, checkpoints,
fault-tolerant loop, and either the pjit TP+DP path or the paper's
flexible-pipeline path (--dist pipeline).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 20 --batch 8 --seq 64 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse

import jax

from repro import optim
from repro.configs import get as get_arch
from repro.configs.base import reduced as reduce_cfg
from repro.data.pipeline import DataConfig, make_stream
from repro.launch import steps as STEPS
from repro.models import transformer as T
from repro.runtime import fault_tolerance as FT


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optim.adamw_init(params, cfg.opt_moment_dtype)
    n = T.param_count(cfg)
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    dc = DataConfig(global_batch=args.batch, seq_len=args.seq,
                    vocab=cfg.vocab)
    stream = make_stream(cfg, dc)
    lr = optim.wsd_schedule(args.lr, warmup=min(100, args.steps // 10 + 1),
                            total=args.steps)
    step = jax.jit(STEPS.make_train_step(cfg, lr=lr, remat=False))

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step(params, opt_state, batch)
        return (params, opt_state), metrics

    logged = []

    def log_metrics(s):
        print(s, flush=True)

    state = (params, opt_state)
    i = [0]

    def wrapped(state, batch):
        state, m = step_fn(state, batch)
        if i[0] % args.log_every == 0:
            log_metrics(f"step {i[0]:5d} loss {float(m['loss']):.4f} "
                        f"gnorm {float(m['grad_norm']):.3f}")
        logged.append(float(m["loss"]))
        i[0] += 1
        return state, m

    state, rs = FT.run_loop(
        state=state, step_fn=wrapped, stream=stream, ckpt_dir=args.ckpt,
        total_steps=args.steps, ckpt_every=args.ckpt_every)
    print(f"[train] done: final loss {logged[-1]:.4f} "
          f"(first {logged[0]:.4f}), restarts={rs.restarts}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
