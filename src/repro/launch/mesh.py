"""Production mesh construction.

Importing this module never touches jax device state; all meshes are built
inside functions (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: one pod = (16, 16) chips over
    (data, model); two pods = (2, 16, 16) over (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, n_pod: int = 1):
    """Small host-device mesh for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=n_data*n_model*n_pod)."""
    if n_pod > 1:
        return jax.make_mesh((n_pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
