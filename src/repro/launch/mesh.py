"""Production mesh construction.

Importing this module never touches jax device state; all meshes are built
inside functions (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: one pod = (16, 16) chips over
    (data, model); two pods = (2, 16, 16) over (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def device_slices(n_slices: int, devices=None) -> list[list]:
    """Split the device list into ``n_slices`` contiguous near-equal
    slices (sizes differ by at most one) — the replica pool's stage-shard
    mode gives each pipeline replica one slice and stage-pipelines across
    it. With more slices than devices, slices wrap round-robin so every
    replica still owns a device (they then share, which is exactly the
    forced-host-device CPU case)."""
    if n_slices < 1:
        raise ValueError(f"n_slices={n_slices} < 1")
    devs = list(jax.devices() if devices is None else devices)
    if not devs:
        raise ValueError("no devices to slice")
    if n_slices >= len(devs):
        return [[devs[i % len(devs)]] for i in range(n_slices)]
    base, extra = divmod(len(devs), n_slices)
    out, i = [], 0
    for s in range(n_slices):
        k = base + (1 if s < extra else 0)
        out.append(devs[i:i + k])
        i += k
    return out


def make_debug_mesh(n_data: int = 2, n_model: int = 2, n_pod: int = 1):
    """Small host-device mesh for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=n_data*n_model*n_pod)."""
    if n_pod > 1:
        return jax.make_mesh((n_pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
