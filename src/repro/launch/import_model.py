"""Import an arbitrary CNN into the serving zoo: the compiler CLI.

The one-command front door over ``repro.compiler``: read a model
description (a ``.json`` graph spec, or a ``.onnx`` file when the
optional ``onnx`` package is installed), lower it onto the engine
contract, quantize it with the shared serving conventions, generate +
cross-check its int8 golden parity record (exact-f32 generate, int32
oracle verify — the same bit-identical-routes contract ``tests/golden``
pins for the paper models), and finish with a short serve smoke through
:func:`repro.serving.build_server` so "imported" means *served*, not
just compiled.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.import_model examples/lenet.json
  PYTHONPATH=src python -m repro.launch.import_model examples/lenet.json \
      --golden-out lenet_golden.npz --serve-frames 0   # import+check only
  PYTHONPATH=src python -m repro.launch.import_model model.onnx \
      --bits 16 --batch 8 --stages 2
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro import compiler
from repro.serving.server import (ProgramRegistry, ServerConfig,
                                  build_server, synthetic_stream_like)


def import_and_serve(source, *, name: str | None = None, bits: int = 8,
                     seed: int = 0, theta: int | None = None,
                     golden_check: bool = True, golden_out=None,
                     serve_frames: int = 8, batch: int = 4,
                     stages: int = 1, verbose: bool = True) -> dict:
    """The CLI's engine, importable for tests: import -> golden-check ->
    serve smoke. Returns a result dict (model card + golden digest +
    serve outcomes). ``serve_frames=0`` skips the serve smoke."""
    t0 = time.perf_counter()
    graph = compiler.import_graph(source)
    model, params = compiler.lower_graph(graph)
    reg = ProgramRegistry()
    model_id, golden = reg.register_imported(
        graph, name=name, bits=bits, seed=seed, theta=theta,
        golden_check=golden_check)
    prog = reg.get(model_id)
    import_s = time.perf_counter() - t0
    if golden_out is not None:
        compiler.save_golden(golden_out, golden)
    result = {
        "model": model_id,
        "source": str(source) if not isinstance(source, dict) else "<dict>",
        "bits": bits,
        "seed": seed,
        "params": "imported" if params is not None else "seeded",
        "input_hw": model.input_hw,
        "input_ch": model.input_ch,
        "layers": [{"name": l.name, "kind": l.kind, "in_ch": l.in_ch,
                    "out_ch": l.out_ch, "k": l.kernel, "stride": l.stride}
                   for l in model.layers],
        "modeled_fps_alg1": round(prog.fps(), 3),
        "golden": {
            "acc_crc": int(golden["acc_crc"]),
            "acc_sample_head": [int(v) for v in golden["acc_sample"][:4]],
            "top1": [int(v) for v in golden["top1"]],
            "checked": bool(golden_check),
            "routes": "f32 -> oracle" if golden_check else "f32 only",
            "saved": str(golden_out) if golden_out is not None else None,
        },
        "import_s": round(import_s, 3),
    }
    if verbose:
        kinds = ", ".join(f"{l.name}({l.kind})" for l in model.layers)
        print(f"[import_model] {model_id}: {len(model.layers)} engine "
              f"layers [{kinds}] from {result['source']}")
        print(f"[import_model] golden acc_crc={result['golden']['acc_crc']}"
              + (" verified across MAC routes (f32 -> oracle)"
                 if golden_check else " (check skipped)"))
    if serve_frames > 0:
        frames = synthetic_stream_like(model, serve_frames, seed)
        cfg = ServerConfig(batch=batch, stages=stages, bits=bits,
                           seed=seed, theta=theta,
                           calib_frames=max(3 * batch, 12))
        with build_server(reg, cfg, verbose=False) as srv:
            reqs = [srv.submit(model_id, f) for f in frames]
            outs = [r.result(timeout=120.0) for r in reqs]
            outcomes = [r.outcome for r in reqs]
            stats = srv.stats()
        result["serve"] = {
            "frames": serve_frames,
            "batch": batch,
            "stages": stages,
            "outcomes": sorted(set(outcomes)),
            "completed": stats["totals"]["completed"],
            "steady_fps": stats["models"][model_id]["steady_fps"],
            "sample_top1": [int(np.asarray(o).reshape(-1).argmax())
                            if np.asarray(o).size > 1 else int(o)
                            for o in outs[:4]],
        }
        if verbose:
            print(f"[import_model] serve smoke: "
                  f"{result['serve']['completed']}/{serve_frames} frames "
                  f"completed through build_server "
                  f"(steady {result['serve']['steady_fps']:.2f} fps)")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("source",
                    help="model to import: a .json graph spec, or a "
                         ".onnx file (needs the optional onnx package)")
    ap.add_argument("--name", default=None,
                    help="registry id (default: the spec's model name)")
    ap.add_argument("--bits", type=int, default=8, choices=(8, 16))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--theta", type=int, default=None,
                    help="DSP budget for the Algorithm-1 plan "
                         "(default: Table I convention for --bits)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the cross-route golden verification")
    ap.add_argument("--golden-out", default=None,
                    help="also save the golden record as .npz")
    ap.add_argument("--serve-frames", type=int, default=8,
                    help="serve smoke length (0 = import+check only)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--json", action="store_true",
                    help="print the full result dict as JSON")
    args = ap.parse_args(argv)

    result = import_and_serve(
        args.source, name=args.name, bits=args.bits, seed=args.seed,
        theta=args.theta, golden_check=not args.no_check,
        golden_out=args.golden_out, serve_frames=args.serve_frames,
        batch=args.batch, stages=args.stages, verbose=True)
    if args.json:
        print(json.dumps(result, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
