"""Serving launcher: batched prefill + decode with the KV/state cache.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get as get_arch
from repro.configs.base import reduced as reduce_cfg
from repro.launch import steps as STEPS
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen
    cache = T.init_cache(cfg, args.batch, max_len)

    prefill = jax.jit(STEPS.make_prefill_step(cfg))
    decode = jax.jit(STEPS.make_serve_step(cfg))

    key = jax.random.PRNGKey(1)
    if cfg.frontend_stub and cfg.family != "enc_dec":
        batch = {"embeds": jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16),
            "positions": jnp.broadcast_to(
                jnp.arange(args.prompt_len)[None, :, None],
                (args.batch, args.prompt_len, 3)).astype(jnp.int32)}
    else:
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)}
        if cfg.family == "enc_dec":
            batch["enc_embeds"] = jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model),
                jnp.bfloat16)

    t0 = time.time()
    logits_last, cache = prefill(params, cache, batch)
    tok = jnp.argmax(logits_last.astype(jnp.float32), -1)[:, None]
    t1 = time.time()
    outs = [tok]
    for _ in range(args.gen - 1):
        if cfg.frontend_stub and cfg.family != "enc_dec":
            step_in = {"embeds": jnp.take(params["embed"], tok, axis=0
                                          ).astype(jnp.bfloat16),
                       "positions": jnp.zeros((args.batch, 1, 3), jnp.int32)}
        else:
            step_in = {"tokens": tok}
        nxt, cache = decode(params, cache, step_in)
        tok = nxt[:, None]
        outs.append(tok)
    toks = jnp.concatenate(outs, 1)
    dt = time.time() - t1
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tok in "
          f"{t1-t0:.2f}s; decoded {args.gen} x {args.batch} seqs in "
          f"{dt:.2f}s ({args.gen*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("[serve] sample token ids:", toks[0, :8].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
