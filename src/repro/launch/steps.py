"""Jittable step functions: train_step (grad + clip + AdamW), prefill_step
and serve_step (decode with cache). Shared by train.py, serve.py and
dryrun.py."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro import optim


def make_train_step(cfg: ModelConfig, *, lr=3e-4, remat: bool = True,
                    clip_norm: float = 1.0):
    moment_dtype = cfg.opt_moment_dtype

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, remat=remat), has_aux=True
        )(params)
        grads, gn = optim.clip_by_global_norm(grads, clip_norm)
        params, opt_state = optim.adamw_update(
            params, grads, opt_state, lr=lr, moment_dtype=moment_dtype)
        metrics = dict(metrics, grad_norm=gn)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, cache, batch):
        logits, cache, _ = T.forward(params, cfg, batch, cache=cache)
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: new token against the running cache (greedy)."""
    def serve_step(params, cache, batch):
        logits, cache, _ = T.forward(params, cfg, batch, cache=cache)
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok, cache

    return serve_step


def abstract_state(cfg: ModelConfig):
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    def make():
        p = T.init_params(cfg, jax.random.PRNGKey(0))
        o = optim.adamw_init(p, cfg.opt_moment_dtype)
        return p, o
    return jax.eval_shape(make)
