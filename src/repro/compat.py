"""Version-compat shims for jax APIs that moved between releases.

The repo targets the newest jax spellings (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``); on older jaxlib
(e.g. the pinned 0.4.x CPU image) these fall back to the experimental /
thread-resource equivalents with identical call sites.
"""

from __future__ import annotations

import contextlib

import jax

try:
    _shard_map = jax.shard_map
    _LEGACY_SHARD_MAP = False
except AttributeError:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    _LEGACY_SHARD_MAP = True


def shard_map(f=None, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename and
    no-positional-function (decorator via functools.partial) use handled."""
    if _LEGACY_SHARD_MAP and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if f is None:
        return lambda g: shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


try:
    get_abstract_mesh = jax.sharding.get_abstract_mesh

    def _ambient_mesh():
        m = get_abstract_mesh()
        return None if m is None or not m.axis_names else m
except AttributeError:  # jax < 0.5: read the thread-resource mesh
    def get_abstract_mesh():
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m

    _ambient_mesh = get_abstract_mesh


def ambient_mesh():
    """The mesh made current by :func:`set_mesh`, or None outside one."""
    return _ambient_mesh()


try:
    set_mesh = jax.set_mesh
except AttributeError:  # jax < 0.6: Mesh is itself the context manager
    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (old)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
