"""Synthetic sharded data pipeline.

Deterministic, seekable token/image streams: every (step, host) pair
regenerates its shard independently — exactly what checkpoint/restart and
elastic rescaling need (resume = seek(step); rescale = re-partition host
ids). A real deployment swaps `_tokens_for` for file-backed readers with the
same interface.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 1234
    # 0 -> uniform token ids; >0 -> Zipf(alpha)-distributed ids (realistic
    # frequency skew; gives training curves a learnable unigram signal).
    zipf_alpha: float = 0.0


class TokenStream:
    """Infinite synthetic LM batches, sharded by host."""

    def __init__(self, dc: DataConfig):
        assert dc.global_batch % dc.n_hosts == 0
        self.dc = dc
        self.local_batch = dc.global_batch // dc.n_hosts
        self._step = 0

    def seek(self, step: int) -> None:
        self._step = step

    @property
    def step(self) -> int:
        return self._step

    def _batch_for(self, step: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, step, dc.host_id]))
        shape = (self.local_batch, dc.seq_len + 1)
        if dc.zipf_alpha > 0:
            ranks = np.arange(1, dc.vocab + 1, dtype=np.float64)
            p = ranks ** -dc.zipf_alpha
            p /= p.sum()
            toks = rng.choice(dc.vocab, size=shape, p=p).astype(np.int32)
        else:
            toks = rng.integers(0, dc.vocab, size=shape, dtype=np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self._batch_for(self._step)
        self._step += 1
        return b


class EmbedStream(TokenStream):
    """Precomputed-embedding batches for frontend-stub archs (vlm/enc-dec)."""

    def __init__(self, dc: DataConfig, cfg: ModelConfig,
                 enc_len: int | None = None):
        super().__init__(dc)
        self.cfg = cfg
        self.enc_len = enc_len

    def _batch_for(self, step: int) -> dict:
        base = super()._batch_for(step)
        dc, cfg = self.dc, self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, step, dc.host_id, 7]))
        if cfg.family == "enc_dec":
            enc = rng.standard_normal(
                (self.local_batch, self.enc_len or dc.seq_len, cfg.d_model),
                dtype=np.float32)
            base["enc_embeds"] = jnp.asarray(enc, jnp.bfloat16)
        else:  # vlm: patch embeddings + 3D M-RoPE positions
            emb = rng.standard_normal(
                (self.local_batch, dc.seq_len, cfg.d_model), dtype=np.float32)
            base["embeds"] = jnp.asarray(emb, jnp.bfloat16)
            pos = np.broadcast_to(
                np.arange(dc.seq_len, dtype=np.int32)[None, :, None],
                (self.local_batch, dc.seq_len, 3))
            base["positions"] = jnp.asarray(pos)
            del base["tokens"]
        return base


def make_stream(cfg: ModelConfig, dc: DataConfig):
    if cfg.frontend_stub:
        return EmbedStream(dc, cfg)
    return TokenStream(dc)
