"""AdamW with configurable moment dtype (fp32 / bf16 / int8-blockwise),
global-norm clipping, a warmup-stable-decay schedule, and int8 gradient
compression with error feedback (the cross-pod all-reduce trick).

The int8 moment option is what lets deepseek-v3-671b's optimizer state fit
512 x 16 GB HBM (see DESIGN.md): blockwise (128) absmax-scaled int8, the
bitsandbytes-style formulation.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_BLOCK = 128


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (possibly quantized: (q, scale))
    nu: Any          # second moment
    err: Any | None  # error-feedback residual for grad compression (or None)


# ---------------------------------------------------------------------------
# Blockwise int8 moment quantization
# ---------------------------------------------------------------------------


_DYN_K = 65535.0      # companding constant: ~4.8 decades of dynamic range


def _q8_encode(x: jnp.ndarray, code: str = "linear"):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    amax = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True),
                       1e-12)
    if code == "dynamic":
        # mu-law companding (bnb-style dynamic quantization): linear int8
        # zeroes small second moments and Adam explodes; log-spaced codes
        # keep ~9% relative error across the whole block range.
        u = jnp.log1p(jnp.abs(blocks) / amax * _DYN_K) / jnp.log1p(_DYN_K)
        q = jnp.clip(jnp.round(u * 127.0), 0, 127) * jnp.sign(blocks)
        q = q.astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(blocks / (amax / 127.0)), -127,
                     127).astype(jnp.int8)
    return {"q": q, "scale": (amax / 127.0 if code == "linear" else amax
                              ).astype(jnp.float32),
            "shape": jnp.asarray(x.shape + (1 if code == "linear" else 2,))}


def _q8_decode(enc, shape, code: str = "linear") -> jnp.ndarray:
    if code == "dynamic":
        u = jnp.abs(enc["q"].astype(jnp.float32)) / 127.0
        mag = jnp.expm1(u * jnp.log1p(_DYN_K)) / _DYN_K * enc["scale"]
        flat = (mag * jnp.sign(enc["q"].astype(jnp.float32))).reshape(-1)
    else:
        flat = (enc["q"].astype(jnp.float32) * enc["scale"]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def _moment_like(p, dtype: str):
    if dtype == "int8":
        return _q8_encode(jnp.zeros_like(p, jnp.float32), code="dynamic")
    return jnp.zeros_like(p, jnp.dtype(dtype))


def adamw_init(params, moment_dtype: str = "float32",
               error_feedback: bool = False) -> AdamWState:
    mu = jax.tree.map(lambda p: _moment_like(p, moment_dtype), params)
    nu = jax.tree.map(lambda p: _moment_like(p, moment_dtype), params)
    err = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
           if error_feedback else None)
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu, err)


def _read_moment(m, shape, dtype: str):
    if dtype == "int8":
        return _q8_decode(m, shape, code="dynamic")
    return m.astype(jnp.float32)


def _write_moment(x, dtype: str):
    if dtype == "int8":
        return _q8_encode(x, code="dynamic")
    return x.astype(jnp.dtype(dtype))


def adamw_update(params, grads, state: AdamWState, *,
                 lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 moment_dtype: str = "float32"):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    is_q8 = moment_dtype == "int8"

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        m = _read_moment(mu, p.shape, moment_dtype)
        v = _read_moment(nu, p.shape, moment_dtype)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_p = (p.astype(jnp.float32)
                 - lr_t * (upd_ + weight_decay * p.astype(jnp.float32)))
        return (new_p.astype(p.dtype), _write_moment(m, moment_dtype),
                _write_moment(v, moment_dtype))

    if is_q8:
        # tree over (params, grads, mu, nu) where mu/nu are dict-encoded
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        flat_mu = _flatten_encoded(state.mu, tdef)
        flat_nu = _flatten_encoded(state.nu, tdef)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    else:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_mu, new_nu, state.err)


def _flatten_encoded(tree, tdef):
    """Flatten a tree whose leaves are {"q","scale","shape"} dicts to match
    the param treedef."""
    leaves = []

    def rec(node):
        if isinstance(node, dict) and set(node) == {"q", "scale", "shape"}:
            leaves.append(node)
        elif isinstance(node, dict):
            for k in sorted(node):
                rec(node[k])
        elif isinstance(node, (list, tuple)):
            for x in node:
                rec(x)
        else:
            leaves.append(node)

    rec(tree)
    return leaves


# ---------------------------------------------------------------------------
# Gradient clipping / schedule / compression
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads, max_norm: float = 1.0):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1):
    """Warmup-stable-decay (linear warmup, constant, cosine tail)."""
    def lr(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        decay_start = total * (1 - decay_frac)
        t = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0, 1)
        return peak_lr * w * (0.5 * (1 + jnp.cos(jnp.pi * t))
                              if decay_frac > 0 else 1.0)
    return lr


def compress_grads(grads, err):
    """int8 blockwise compression with error feedback: returns
    (compressed tree, new_err). Decompress with `decompress_grads` after the
    cross-pod all-reduce — 4x less ICI traffic on the pod axis."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        enc = _q8_encode(g32)
        deq = _q8_decode(enc, g.shape)
        return enc, g32 - deq
    encs = jax.tree.map(one, grads, err)
    comp = jax.tree.map(lambda t: t[0], encs,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], encs,
                           is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_err


def decompress_grads(comp, shapes):
    return jax.tree.map(
        lambda enc, ref: _q8_decode(enc, ref.shape), comp, shapes,
        is_leaf=lambda n: isinstance(n, dict) and set(n) == {"q", "scale",
                                                             "shape"})
