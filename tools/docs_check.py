"""Docs drift gate: every concrete reference in the operator docs must
resolve against the tree it documents.

Scans README.md, DESIGN.md, and docs/OPERATIONS.md for

* repo paths (``src/repro/...``, ``benchmarks/...``, ``examples/...``,
  ``tests/...``, ``docs/...``, ``tools/...``) and top-level ``*.md``
  mentions — the file or directory must exist;
* dotted module references (``repro.serving.elastic``,
  ``repro.core.program.EngineProgram``) — resolved component by
  component under ``src/``; trailing attribute names on a module are
  fine, and a name re-exported by a package ``__init__.py`` counts; a
  missing *package* component is drift;
* ``make <target>`` invocations inside code spans or fenced blocks —
  the target must exist in the Makefile (prose like "make this fast"
  is not an invocation);
* ``--flag`` tokens — the flag must be declared by some
  ``add_argument`` under ``src/repro/launch/`` or ``benchmarks/``
  (plus a small allowlist for flags owned by other tools: XLA, pytest).

Pure text scan — no jax import, no repo code import — so it runs in the
lint job in seconds. Exit status 1 lists every dangling reference.

  python tools/docs_check.py            # = make docs-check
"""

from __future__ import annotations

import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ("README.md", "DESIGN.md", "docs/OPERATIONS.md")

# Path-looking tokens rooted at a directory this check owns. Generated
# artifacts (BENCH_*.json) are documented but not committed — skipped.
PATH_RE = re.compile(
    r"\b(?:src/repro|benchmarks|examples|tests|docs|tools)"
    r"(?:/[A-Za-z0-9_.*-]+)+")
TOP_MD_RE = re.compile(r"\b([A-Z][A-Z_a-z]*\.md)\b")
MODULE_RE = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
MAKE_RE = re.compile(r"\bmake ([a-z][a-z0-9_-]*)")
FLAG_RE = re.compile(r"(--[a-z][a-z0-9_-]+)")

# Flags that appear in the docs but belong to other tools.
FLAG_ALLOW = {
    "--xla_force_host_platform_device_count",   # XLA_FLAGS
    "--timeout", "--timeout-method", "--last-failed",  # pytest
}


def _declared_flags() -> set[str]:
    flags: set[str] = set(FLAG_ALLOW)
    for pattern in ("src/repro/launch/*.py", "benchmarks/*.py",
                    "tools/*.py"):
        for py in ROOT.glob(pattern):
            flags.update(FLAG_RE.findall(py.read_text()))
    return flags


def _make_targets() -> set[str]:
    targets: set[str] = set()
    for line in (ROOT / "Makefile").read_text().splitlines():
        m = re.match(r"^([A-Za-z0-9_-]+):", line)
        if m:
            targets.add(m.group(1))
    return targets


def _check_path(tok: str) -> bool:
    tok = tok.rstrip(".,:;")
    if "*" in tok:      # glob mention like benchmarks/baselines/*.json
        return any(ROOT.glob(tok))
    return (ROOT / tok).exists()


def _code_spans(text: str) -> str:
    """Concatenate the document's inline code spans and fenced code
    blocks — the only places ``make <target>`` means an invocation."""
    fenced = re.findall(r"```.*?```", text, flags=re.S)
    inline = re.findall(r"`[^`\n]+`", text)
    return "\n".join(fenced + inline)


def _check_module(ref: str) -> bool:
    """Walk ``repro.a.b.C`` under src/: descend packages; once a
    component resolves to a module file, the rest are attributes (not
    checked), and a name re-exported by the package's ``__init__.py``
    resolves too. A component missing while still inside a package is
    a dangling module reference."""
    parts = ref.split(".")
    cur = ROOT / "src"
    for comp in parts:
        if (cur / comp).is_dir():
            cur = cur / comp
        elif (cur / f"{comp}.py").is_file():
            return True          # rest are attrs on this module
        else:
            init = cur / "__init__.py"
            return (init.is_file()
                    and re.search(rf"\b{re.escape(comp)}\b",
                                  init.read_text()) is not None)
    return True                  # package reference, fully resolved


def main() -> int:
    errors: list[str] = []
    flags = _declared_flags()
    targets = _make_targets()
    for doc in DOCS:
        path = ROOT / doc
        if not path.is_file():
            errors.append(f"{doc}: file missing")
            continue
        text = path.read_text()
        for tok in sorted(set(PATH_RE.findall(text))):
            if not _check_path(tok):
                errors.append(f"{doc}: path {tok!r} does not exist")
        for tok in sorted(set(TOP_MD_RE.findall(text))):
            if not (ROOT / tok).is_file() and not (ROOT / "docs" / tok).is_file():
                errors.append(f"{doc}: document {tok!r} does not exist")
        for ref in sorted(set(MODULE_RE.findall(text))):
            if not _check_module(ref):
                errors.append(f"{doc}: module reference {ref!r} does "
                              f"not resolve under src/")
        for tgt in sorted(set(MAKE_RE.findall(_code_spans(text)))):
            if tgt not in targets:
                errors.append(f"{doc}: make target {tgt!r} not in "
                              f"Makefile")
        for flag in sorted(set(FLAG_RE.findall(text))):
            if flag not in flags:
                errors.append(f"{doc}: flag {flag!r} declared by no "
                              f"CLI under src/repro/launch/ or "
                              f"benchmarks/")
    if errors:
        print(f"[docs-check] {len(errors)} dangling reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"[docs-check] OK: {', '.join(DOCS)} resolve against the tree")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
